"""End-to-end driver (the paper's deployment scenario): serve a small LM with
batched requests through the quantized KMM engine and report throughput plus
the paper's multiplier-compute-efficiency accounting.

    PYTHONPATH=src python examples/serve_quantized.py [--arch gemma-2b]
        [--quant w12] [--requests 8] [--d-model 256] [--layers 4]

Uses a reduced config sized for this CPU container by default; on real
accelerators pass --full-size.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.context import ExecContext
from repro.core.dispatch import conv_mults_per_product, select_mode
from repro.models import lm
from repro.models.config import count_params
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--quant", default="w12",
                    choices=["none", "w8", "w12", "mixed"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--backend", "--quant-backend", dest="backend",
                    default="xla", choices=["xla", "pallas"],
                    help="'pallas' routes every quantized matmul through "
                         "the fused single-pass kernel (DESIGN.md §11)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full_size, quant=args.quant)
    print(f"arch={cfg.name} quant={args.quant} "
          f"params={count_params(cfg)/1e6:.1f}M")

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_seq=96, batch_size=args.batch,
                    context=ExecContext(backend=args.backend))
    rng = np.random.default_rng(0)
    # ragged prompts + mixed budgets: the continuous-batching scheduler
    # admits each request into the first freed slot (no group barrier)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             size=rng.integers(4, 17))),
                    max_new_tokens=int(rng.integers(1, args.max_new + 1)))
            for _ in range(args.requests)]
    t0 = time.time()
    stats = engine.generate(reqs)
    wall = time.time() - t0
    ttft = [r.ttft_s for r in stats.requests]
    print(f"served {len(reqs)} requests in {wall:.1f}s "
          f"(prefill {stats.prefill_s:.2f}s, decode {stats.decode_s:.2f}s, "
          f"{stats.generated_tokens} tokens, {stats.tokens_per_s:.1f} tok/s, "
          f"ttft mean {np.mean(ttft)*1e3:.0f}ms)")
    for i, r in enumerate(reqs[:3]):
        print(f"  req{i}: {r.generated}")

    # Paper accounting: m-bit MXU passes spent vs conventional algebra.
    if args.quant != "none":
        q = cfg.quant
        bits = q.default_bits
        plan = select_mode(bits, q.m)
        conv = conv_mults_per_product(bits, q.m)
        print(f"w={bits}: {plan.mode.value} spends {plan.mults_per_product} "
              f"m-bit products per w-bit MAC; conventional needs {conv} "
              f"-> multiplier-efficiency roof {conv/plan.mults_per_product:.2f}"
              f" (paper Eq. 15)")


if __name__ == "__main__":
    main()
