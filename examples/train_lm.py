"""Train a ~100M-parameter LM for a few hundred steps with the full substrate:
deterministic data pipeline, AdamW, async checkpointing, auto-resume, and
(optionally) quantized-KMM forward matmuls (integer quantized training, STE).

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 20 --tiny   # CI-sized

Interrupt it and re-run: it resumes from the latest checkpoint.
"""
import argparse
import logging

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import single_device_mesh
from repro.models.config import Block, count_params
from repro.quant.policy import QuantConfig
from repro.train import optim
from repro.train.loop import TrainConfig, run_training


def model_100m(quant: str):
    base = get_config("llama3.2-1b", smoke=True)
    cfg = base.scaled_down(
        name="llama-100m", d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32000, n_periods=8, pattern=(Block("attn"),))
    if quant != "none":
        cfg = cfg.with_quant(QuantConfig(enabled=True,
                                         default_bits=int(quant[1:])))
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--quant", default="none", choices=["none", "w8", "w12"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-sized model for quick runs")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    cfg = get_config("llama3.2-1b", smoke=True) if args.tiny \
        else model_100m(args.quant)
    print(f"model {cfg.name}: {count_params(cfg)/1e6:.1f}M params")
    tc = TrainConfig(
        steps=args.steps, log_every=10, ckpt_every=50,
        ckpt_dir=args.ckpt_dir,
        optimizer=optim.AdamWConfig(lr=6e-4, warmup_steps=20,
                                    total_steps=args.steps))
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch, seed=0)
    result = run_training(cfg, single_device_mesh(), tc, data)
    first, last = list(result.losses.values())[0], \
        list(result.losses.values())[-1]
    print(f"loss {first:.3f} -> {last:.3f} over {result.final_step} steps "
          f"(resumed_from={result.restored_from})")


if __name__ == "__main__":
    main()
