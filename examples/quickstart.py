"""Quickstart: the KMM public API in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. exact integer Karatsuba matrix multiplication (Algorithm 4),
2. the precision-scalable dispatch rule (Fig. 10),
3. the Pallas MXU kernel (interpret mode on CPU),
4. the complexity/area models behind the paper's figures.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import kmm_n, mm_n, select_mode, max_exact_k
from repro.core.complexity import kmm_arith, ksmm_arith, mm_arith
from repro.core.area import au_efficiency_vs_mm1
from repro.kernels.ops import int_gemm
from repro.kernels.ref import ref_int_gemm_i64


def main():
    rng = np.random.default_rng(0)

    # --- 1. KMM is exact integer matmul with 3^r digit products -------------
    w = 12                       # operand bitwidth
    k = min(max_exact_k(w), 64)  # int32-exact contraction bound
    a = rng.integers(-2**11, 2**11, (8, k)).astype(np.int32)
    b = rng.integers(-2**11, 2**11, (k, 8)).astype(np.int32)
    out = np.asarray(kmm_n(jnp.array(a), jnp.array(b), w=w, n=2))
    assert (out.astype(np.int64) == ref_int_gemm_i64(a, b)).all()
    print(f"KMM_2^[{w}]: exact, 3 digit products (MM_2 needs 4)")

    # --- 2. precision-scalable dispatch (paper Fig. 10) ----------------------
    for bits in (8, 12, 14, 15, 16):
        plan = select_mode(bits, m=8)
        print(f"  w={bits:2d} -> {plan.mode.value:5s} "
              f"({plan.passes} tile passes, roof {4/max(plan.passes,1):.2f}x"
              f" conventional)" if bits > 8 else
              f"  w={bits:2d} -> {plan.mode.value:5s} (1 tile pass)")

    # --- 3. Pallas MXU kernel (fixed-precision KMM architecture, Fig. 8) ----
    a = rng.integers(-2**11, 2**11, (128, 256)).astype(np.int32)
    b = rng.integers(-2**11, 2**11, (256, 128)).astype(np.int32)
    out = np.asarray(int_gemm(jnp.array(a), jnp.array(b), w=12,
                              backend="pallas"))
    ref = ref_int_gemm_i64(a, b).astype(np.float64)
    print(f"Pallas kmm2_gemm: max rel err "
          f"{np.abs(out-ref).max()/np.abs(ref).max():.2e}")

    # --- 4. the paper's cost models ------------------------------------------
    d = 64
    print(f"arithmetic ops (d={d}, n=2): MM {mm_arith(2, d):.3g}, "
          f"KSMM {ksmm_arith(2, d):.3g}, KMM {kmm_arith(2, d):.3g}")
    for width in (16, 32, 64):
        eff = au_efficiency_vs_mm1("kmm", width).relative
        print(f"  AU efficiency vs MM1 @ w={width}: {eff:.2f}x")


if __name__ == "__main__":
    main()
