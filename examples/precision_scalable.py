"""The precision-scalable architecture, executable (paper Fig. 10/11).

Sweeps input bitwidths 4..16 over the same integer GEMM and shows which mode
the dispatch rule picks, how many m-bit MXU passes it spends, the paper's
efficiency roof, and the measured CPU wall-time — the 3-vs-4-pass gap of
KMM2 vs MM2 is directly visible in wall time.

    PYTHONPATH=src python examples/precision_scalable.py [--size 768]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import conv_mults_per_product, select_mode
from repro.kernels.ops import int_gemm_jit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=768)
    args = ap.parse_args()
    n = args.size
    rng = np.random.default_rng(0)
    print(f"{'w':>3} {'mode':>5} {'passes':>6} {'roof':>5} {'us/call':>9}")
    for w in (4, 6, 8, 10, 12, 14, 15, 16):
        lim = 2 ** (w - 1)
        a = jnp.array(rng.integers(-lim, lim, (n, n)), jnp.int32)
        b = jnp.array(rng.integers(-lim, lim, (n, n)), jnp.int32)
        plan = select_mode(w, 8)
        fn = lambda: int_gemm_jit(a, b, w)
        fn().block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn()
        out.block_until_ready()
        us = (time.perf_counter() - t0) / 3 * 1e6
        roof = conv_mults_per_product(w, 8) / plan.mults_per_product
        print(f"{w:>3} {plan.mode.value:>5} {plan.passes:>6} {roof:>5.2f} "
              f"{us:>9.0f}")
    print("\nKMM2 rows (w 9-14) run 3 digit-products instead of MM2's 4:")
    print("expect their wall time ~0.75x of the w=15/16 rows.")


if __name__ == "__main__":
    main()
