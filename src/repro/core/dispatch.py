"""Precision-scalable execution-mode dispatch (paper Section IV-C, Fig. 10).

Given input bitwidth ``w`` and multiplier bitwidth ``m`` the architecture
selects:

  * ``w <= m``          -> MM1  (1 tile pass)
  * ``m < w <= 2m - 2``  -> KMM2 (3 tile passes; the ``2m-2`` bound keeps the
                            ``As = A1 + A0`` digits within ``m`` bits)
  * ``2m - 2 < w <= 2m`` -> MM2  (4 tile passes)

Larger ``w`` recurses (fixed-precision architecture, Fig. 8): each level of
KMM halves the width (+1 carry bit) until digits fit the multiplier.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass
from typing import List


class Mode(enum.Enum):
    MM1 = "mm1"
    KMM2 = "kmm2"
    MM2 = "mm2"


@dataclass(frozen=True)
class Plan:
    mode: Mode
    w: int            # input bitwidth
    m: int            # multiplier bitwidth
    passes: int       # tile-read passes of the precision-scalable MXU
    digits: int       # n: digits per operand at this level
    recursion: int    # r = ceil(log2 n) levels used

    @property
    def mults_per_product(self) -> int:
        """m-bit multiplications per w-bit product (3^r for KMM, 4^r for MM)."""
        if self.mode is Mode.MM1:
            return 1
        base = 3 if self.mode is Mode.KMM2 else 4
        return base ** self.recursion


def select_mode(w: int, m: int = 8) -> Plan:
    """The paper's single-level dispatch rule (Fig. 10 modes)."""
    if w < 1:
        raise ValueError(f"bitwidth must be >= 1, got {w}")
    if w <= m:
        return Plan(Mode.MM1, w, m, passes=1, digits=1, recursion=0)
    if w <= 2 * m - 2:
        return Plan(Mode.KMM2, w, m, passes=3, digits=2, recursion=1)
    if w <= 2 * m:
        return Plan(Mode.MM2, w, m, passes=4, digits=2, recursion=1)
    # Fixed-precision recursion (Fig. 8): more than one KMM level.
    r = kmm_levels_needed(w, m)
    if r is None:
        raise ValueError(f"w={w} too wide for m={m} multipliers at any depth")
    return Plan(Mode.KMM2, w, m, passes=3 ** r, digits=2 ** r, recursion=r)


def kmm_levels_needed(w: int, m: int) -> int | None:
    """Minimum KMM recursion depth so every leaf digit fits m bits.

    Each level maps width w -> ceil(w/2) + 1 on the widest (Cs) branch.
    """
    width, r = w, 0
    while width > m:
        width = -(-width // 2) + 1
        r += 1
        if r > 8:
            return None
    return r


def conv_mults_per_product(w: int, m: int) -> int:
    """m-bit mults a *conventional* algorithm (SM/MM) needs per w-bit product:
    4**r with r = ceil(log2(ceil(w/m)))  (paper Eq. 13)."""
    r = conv_recursion(w, m)
    return 4 ** r


def conv_recursion(w: int, m: int) -> int:
    n = -(-w // m)
    return math.ceil(math.log2(n)) if n > 1 else 0


def efficiency_roof(w: int, m: int) -> float:
    """Multiplier-compute-efficiency roof of the precision-scalable KMM
    architecture at width w (paper Eq. 15 + mode rule): conventional mult
    count divided by the mode's mult count."""
    plan = select_mode(w, m)
    return conv_mults_per_product(w, m) / plan.mults_per_product


def schedule(widths: List[int], m: int = 8) -> List[Plan]:
    """Plan a mixed-precision workload (one Plan per layer bitwidth)."""
    return [select_mode(w, m) for w in widths]
