"""Precision-scalable execution-mode dispatch (paper Section IV-C, Fig. 10).

Given input bitwidth ``w`` and multiplier bitwidth ``m`` the architecture
selects:

  * ``w <= m``          -> MM1  (1 tile pass)
  * ``m < w <= 2m - 2``  -> KMM2 (3 tile passes; the ``2m-2`` bound keeps the
                            ``As = A1 + A0`` digits within ``m`` bits)
  * ``2m - 2 < w <= 2m`` -> MM2  (4 tile passes)

Larger ``w`` recurses (fixed-precision architecture, Fig. 8): each level of
KMM halves the width (+1 carry bit) until digits fit the multiplier.
"""
from __future__ import annotations

import enum
import functools
import math
from dataclasses import dataclass, replace
from typing import List, Optional, Tuple

from repro.obs import metrics as obs_metrics

# Plan-selection traffic by (variant, backend, bucketed shape).  select_plan
# runs at trace time (host Python) — the counter sees one hit per trace, not
# per executed call, and costs one flag test when metrics are disabled.
_PLANS_SELECTED = obs_metrics.counter(
    "repro_plans_selected_total",
    "select_plan resolutions by variant/backend/bucketed shape",
    labels=("variant", "backend", "bucket", "source"))


class Mode(enum.Enum):
    MM1 = "mm1"
    KMM2 = "kmm2"
    MM2 = "mm2"


@dataclass(frozen=True)
class Plan:
    mode: Mode
    w: int            # input bitwidth
    m: int            # multiplier bitwidth
    passes: int       # tile-read passes of the precision-scalable MXU
    digits: int       # n: digits per operand at this level
    recursion: int    # r = ceil(log2 n) levels used

    @property
    def mults_per_product(self) -> int:
        """m-bit multiplications per w-bit product (3^r for KMM, 4^r for MM)."""
        if self.mode is Mode.MM1:
            return 1
        base = 3 if self.mode is Mode.KMM2 else 4
        return base ** self.recursion


def select_mode(w: int, m: int = 8) -> Plan:
    """The paper's single-level dispatch rule (Fig. 10 modes).

    The ``w == 2m - 1`` boundary deliberately lands in MM2, not KMM2: at
    ``w = 2m - 1`` the digit split is ``h = ceil(w/2) = m``, so the Karatsuba
    pre-adder outputs ``As = A1 + A0`` need ``m + 1`` bits and no longer fit
    the ``m``-bit multiplier operands — the KMM2 window closes at ``2m - 2``
    (the paper's Fig. 10 rule) and the conventional 4-product MM2 covers
    ``2m - 1`` and ``2m``.  This is correct-by-construction, not a silent
    fallback; tests pin it (``test_w_2m_minus_1_boundary_is_mm2``).
    """
    if m < 2:
        raise ValueError(
            f"multiplier bitwidth m must be >= 2, got m={m}: with m < 2 the "
            f"dispatch windows collapse (KMM2's 'm < w <= 2m - 2' band is "
            f"empty and digit splitting cannot produce m-bit operands)")
    if w < 1:
        raise ValueError(f"bitwidth must be >= 1, got {w}")
    if w <= m:
        return Plan(Mode.MM1, w, m, passes=1, digits=1, recursion=0)
    if w <= 2 * m - 2:
        return Plan(Mode.KMM2, w, m, passes=3, digits=2, recursion=1)
    if w <= 2 * m:
        return Plan(Mode.MM2, w, m, passes=4, digits=2, recursion=1)
    # Fixed-precision recursion (Fig. 8): more than one KMM level.
    r = kmm_levels_needed(w, m)
    if r is None:
        raise ValueError(f"w={w} too wide for m={m} multipliers at any depth")
    return Plan(Mode.KMM2, w, m, passes=3 ** r, digits=2 ** r, recursion=r)


def kmm_levels_needed(w: int, m: int) -> int | None:
    """Minimum KMM recursion depth so every leaf digit fits m bits.

    Each level maps width w -> ceil(w/2) + 1 on the widest (Cs) branch.
    """
    width, r = w, 0
    while width > m:
        width = -(-width // 2) + 1
        r += 1
        if r > 8:
            return None
    return r


def conv_mults_per_product(w: int, m: int) -> int:
    """m-bit mults a *conventional* algorithm (SM/MM) needs per w-bit product:
    4**r with r = ceil(log2(ceil(w/m)))  (paper Eq. 13)."""
    r = conv_recursion(w, m)
    return 4 ** r


def conv_recursion(w: int, m: int) -> int:
    n = -(-w // m)
    return math.ceil(math.log2(n)) if n > 1 else 0


def efficiency_roof(w: int, m: int) -> float:
    """Multiplier-compute-efficiency roof of the precision-scalable KMM
    architecture at width w (paper Eq. 15 + mode rule): conventional mult
    count divided by the mode's mult count."""
    plan = select_mode(w, m)
    return conv_mults_per_product(w, m) / plan.mults_per_product


def schedule(widths: List[int], m: int = 8) -> List[Plan]:
    """Plan a mixed-precision workload (one Plan per layer bitwidth)."""
    return [select_mode(w, m) for w in widths]


# ---------------------------------------------------------------------------
# Execution plans + table-backed selection (repro.tune registry seam).
# ---------------------------------------------------------------------------

# Kernel variants the tuner can pick between.  "mm1"/"kmm2"/"mm2" are the
# paper's modes (executed on the Pallas kernels or the XLA digit recursion
# depending on ``backend``); "fused" is the single-pass Pallas kernel
# (in-kernel digit split + zero-point correction + optional dequant
# epilogue, covering the MM1 window, single-level KMM2 at depth 1, and
# 4-digit depth-2 KMM at depth 2 — see kernels/fused_gemm.py);
# "fused_mm2" is the same kernel in its 4-pass conventional boundary mode
# (valid through w <= 2m, the analytic default for the (2m-2, 2m] window
# and a tuner alternative inside the KMM2 window); "xla_ref" is a single
# fused int32 dot_general (valid only within the int32 headroom bound);
# "ffip" is the literal free-pipeline inner-product reference (tiny shapes
# only); "strassen" / "strassen+kmm2" are one tile-level Strassen split
# whose 7 sub-GEMMs re-enter run_plan at w+1 — on the analytic XLA exact
# plan and on the fused Pallas kernel respectively (core/strassen.py) —
# exact-int by construction (int32 ring combines), valid only inside the
# composed headroom bound tune.space.strassen_k_bound derives.
VARIANTS = ("mm1", "kmm2", "mm2", "fused", "fused_mm2", "xla_ref", "ffip",
            "strassen", "strassen+kmm2")

# Integer core, no fp32 combine anywhere.  The strassen variants belong
# here unconditionally: validate() rejects them without combine_int32, and
# their sub-plans are themselves exact-int.
_EXACT_VARIANTS = ("mm1", "xla_ref", "ffip", "strassen", "strassen+kmm2")

# Variants whose recorded tiles reflect a real Pallas measurement (the
# tiles-only adoption path in select_plan).  The strassen variants are
# deliberately excluded: their tiles were measured on the *half-shape*
# sub-GEMMs, so adopting them for a full-shape fused plan would transplant
# geometry tuned for a different problem.
_TILED_VARIANTS = ("mm1", "kmm2", "mm2", "fused", "fused_mm2")


@dataclass(frozen=True)
class GemmShardSpec:
    """How one GEMM's (M, K, N) dims map onto mesh axes.

    ``m_axes``/``n_axes`` shard the output tile grid (each shard runs the
    kernel on its local block; no cross-shard arithmetic, so values are
    bit-identical to the unsharded kernel).  ``k_axes`` splits the
    contraction: each shard computes a partial product and the results are
    ``psum``-combined — exact for exact-int plans (int32 partials sum to the
    true product), but a *different fp32 rounding* for fp32-combine plans,
    which is why ``k_axes`` participates in :func:`numerics_fingerprint` and
    the model-facing negotiation in :mod:`repro.dist.shard_gemm` never
    proposes it for fp32 plans.
    """

    m_axes: Tuple[str, ...] = ()
    n_axes: Tuple[str, ...] = ()
    k_axes: Tuple[str, ...] = ()
    e_axes: Tuple[str, ...] = ()   # expert/group dim of grouped GEMMs


@dataclass(frozen=True)
class ExecPlan:
    """A fully-resolved way to execute one integer GEMM.

    Where :class:`Plan` is the paper's analytic mode decision (bitwidth ->
    mode), an ``ExecPlan`` adds everything the software stack needs to *run*
    it: kernel variant, execution backend, tile sizes, combine precision and
    digit-recursion depth.  Frozen + hashable so it can be a jit static arg.
    """

    variant: str                 # one of VARIANTS
    w: int                       # input bitwidth
    m: int = 8                   # multiplier bitwidth
    backend: str = "xla"         # "xla" | "pallas" (digit variants only)
    block_m: int = 128
    block_n: int = 128
    block_k: int = 256
    combine_int32: bool = False  # int32 post-adder (exact) vs fp32
    depth: int = 1               # digit-recursion levels (digits = 2**depth)
    source: str = "analytic"     # "analytic" | "table" | "prior" (+notes)
    # Fused-kernel epilogue: "none" (raw int32/fp32 accumulator out) or
    # "dequant" (per-token x per-channel scales applied in-kernel).  A
    # call-site property, never persisted in tuning tables — quant/qmatmul
    # stamps it onto the selected plan before running.
    epilogue: str = "none"
    # Mesh layout for shard-mapped execution (repro.dist.shard_gemm); None
    # runs the kernel unsharded.  A call-site property like ``epilogue`` —
    # stamped by the sharded dispatch path, never persisted in tables
    # (table.put() serializes only _ENTRY_FIELDS).
    shard: Optional[GemmShardSpec] = None

    @property
    def digits(self) -> int:
        if self.variant == "fused":
            return 2 ** self.depth      # depth 0 in the MM1 window
        if self.variant == "fused_mm2":
            return 2
        return 2 ** self.depth if self.variant in ("kmm2", "mm2") else 1

    @property
    def mode(self) -> Optional[Mode]:
        if self.variant == "fused":
            return Mode.KMM2 if self.w > self.m else Mode.MM1
        if self.variant in ("mm2", "fused_mm2"):
            return Mode.MM2
        if self.variant == "kmm2":
            return Mode.KMM2
        if self.variant in ("mm1", "xla_ref"):
            return Mode.MM1
        return None

    @property
    def tiles(self) -> Tuple[int, int, int]:
        return (self.block_m, self.block_n, self.block_k)

    @property
    def is_exact_int(self) -> bool:
        """True when the plan computes the mathematically exact integer
        product in int32 (validity-checked against ``max_exact_k``)."""
        if self.variant == "fused" and self.w <= self.m:
            return True              # MM1-window core: one int8 MXU pass
        return self.combine_int32 or self.variant in _EXACT_VARIANTS


def numerics_fingerprint(plan: ExecPlan):
    """Two plans with equal fingerprints produce bit-identical outputs on the
    same operands (given both pass validity).  Exact-int plans all compute
    the same integer — including the strassen tile-split variants, whose
    int32 ring combines reproduce the plain product exactly inside their
    composed headroom bound — so a tuned table may swap strassen in or out
    of the exact class without moving a bit; fp32-combine plans are keyed by
    everything that changes
    rounding: variant, recursion depth and backend (the Pallas path runs on
    centered digit planes + zero-point correction, the XLA path on raw
    digits — same value, different fp32 rounding).  The fused kernel applies
    the *identical* fp32 operation sequence as the staged Pallas KMM2 path
    (asserted by tests/test_fused_gemm.py), so it shares that class; the
    epilogue is part of the fingerprint because a dequantized output is a
    different value than the raw accumulator.

    Sharding (DESIGN.md §12): M/N sharding replicates K, so every output
    element sees the full-K arithmetic of the unsharded kernel — not part of
    the fingerprint.  K sharding splits the fp32 accumulation order, so
    ``shard.k_axes`` IS part of the fp32 fingerprint (exact-int plans sum
    int32 partials exactly and stay in the "exact" class)."""
    if plan.is_exact_int:
        return ("exact", plan.epilogue)
    variant = {"fused": "kmm2", "fused_mm2": "mm2"}.get(plan.variant,
                                                        plan.variant)
    k_axes = plan.shard.k_axes if plan.shard is not None else ()
    return ("fp32", variant, plan.depth, plan.backend, plan.epilogue, k_axes)


DEFAULT_TILES = (128, 128, 256)


def analytic_plan(w: int, m: int = 8, *, backend: str = "xla",
                  exact: bool = False) -> ExecPlan:
    """The paper's dispatch rule as an ExecPlan with default tiles.

    On ``backend="pallas"`` every window through depth-2 recursion routes
    to the fused single-pass kernel (kernels/fused_gemm.py) —
    numerics-identical to the staged kernels (same fingerprint class), one
    HBM round-trip instead of ~6: MM1 and single-level KMM2 as "fused", the
    (2m-2, 2m] boundary as "fused_mm2" (4 passes), and 4-digit recursion
    (``kmm_levels_needed(w, m) == 2``) as "fused" at depth 2 (9 passes).
    Only depth >= 3 keeps the staged variants.
    """
    plan = select_mode(w, m)
    bm, bn, bk = DEFAULT_TILES
    variant = plan.mode.value
    depth = max(plan.recursion, 1) if plan.mode is not Mode.MM1 else 0
    combine_int32 = exact
    if backend == "pallas" and (
            plan.mode is Mode.MM1
            or (plan.mode is Mode.KMM2 and plan.recursion <= 2)):
        variant = "fused"
        combine_int32 = exact or plan.mode is Mode.MM1
    elif backend == "pallas" and plan.mode is Mode.MM2:
        variant = "fused_mm2"
    return ExecPlan(variant=variant, w=w, m=m, backend=backend,
                    block_m=bm, block_n=bn, block_k=bk,
                    combine_int32=combine_int32, depth=depth)


def _padded(dim: int, block: int) -> int:
    return -(-dim // block) * block


def select_plan(shape: Tuple[int, int, int], w: int, *, m: int = 8,
                backend: str = "xla", exact: bool = False,
                table=None, pin_numerics: bool = True,
                context=None) -> ExecPlan:
    """Table-backed execution-plan selection for an (M, K, N) integer GEMM.

    ``context`` (an :class:`repro.core.context.ExecContext`) supersedes the
    scattered kwargs: its ``backend`` wins over ``backend=``, its
    ``tuning_table`` is consulted (without touching the process-global
    registry), and under ``context.mesh`` with the pallas backend the table
    key and validation run on the *per-shard local shape* — the shard-mapped
    kernel tiles its local block, so local M/N (and the VMEM/accumulator
    bounds on the local K) are what a table entry must fit
    (``repro.tune.space.local_shape``).

    Resolution order:

      1. no active tuning table  -> the paper's analytic rule + default tiles
         (exactly the pre-``repro.tune`` behaviour);
      2. active table with a measured entry for this (backend, bucketed
         M/N/K, w) key -> the recorded winner, *validated* against the search
         space's pruning rules (``max_exact_k`` int32-headroom, s8 digit
         bounds, tile sanity) — an invalid entry is discarded, never run;
      3. active table without an entry -> the cost-model prior from
         :mod:`repro.core.complexity` ranks the pruned space.

    ``pin_numerics`` (the default, used by every model-facing path)
    guarantees the returned plan is numerics-identical to the analytic rule:
    a table may swap variant/depth only inside the same
    :func:`numerics_fingerprint` class (e.g. between exact-int32 variants);
    otherwise only tile sizes are adopted — and on the fp32 Pallas path tiles
    are adopted only when they imply the same zero-padding, since padded-K
    fp32 correction terms round differently.  Tuning therefore never changes
    ``quantized_matmul`` results, only how fast they are computed.
    """
    plan = _select_plan_impl(shape, w, m=m, backend=backend, exact=exact,
                             table=table, pin_numerics=pin_numerics,
                             context=context)
    if obs_metrics.enabled():
        _PLANS_SELECTED.inc(plan.variant, plan.backend,
                            "x".join(str(d) for d in _bucket_cached(shape)),
                            plan.source)
    return plan


@functools.lru_cache(maxsize=4096)
def _bucket_cached(shape: Tuple[int, int, int]) -> Tuple[int, int, int]:
    from repro.tune.space import bucket_shape   # lazy: core must not
    return bucket_shape(shape)                  # hard-depend on tune


def _select_plan_impl(shape: Tuple[int, int, int], w: int, *, m: int = 8,
                      backend: str = "xla", exact: bool = False,
                      table=None, pin_numerics: bool = True,
                      context=None) -> ExecPlan:
    if context is not None:
        backend = context.backend
        if table is None and context.tuning_table is not None:
            table = context.resolve_table()
        if context.mesh is not None and backend == "pallas":
            # The shard-mapped kernel runs on the local block: key the
            # table and validate bounds on the per-shard shape.
            shape = context.local_gemm_shape(shape)
    base = analytic_plan(w, m, backend=backend, exact=exact)
    if table is None:
        from repro.tune import table as tune_table   # lazy: core must not
        table = tune_table.get_active_table()        # hard-depend on tune
    if table is None:
        return base
    from repro.tune import space as tune_space
    entry = table.lookup(backend, shape, w, m)
    source = "table"
    if entry is None:
        entry = _prior_plan_cached(tune_space.bucket_shape(shape), w, m,
                                   backend, exact)
        source = "prior"
    if entry is None:
        return base
    entry = replace(entry, w=w, m=m, backend=backend, source=source)
    if tune_space.validate(entry, shape) is not None:
        return base                      # never run a candidate that fails
    if not pin_numerics:
        return entry
    if (numerics_fingerprint(entry) == numerics_fingerprint(base)
            and _k_padding_matches(shape, base, entry)):
        return entry
    # Numerics differ (or the fp32-Pallas K padding would change): adopt
    # tiles only, and only when the entry actually measured tiles — an
    # xla_ref / ffip / xla-backend winner's recorded tiles are meaningless
    # defaults, so keep the analytic plan wholesale.
    if entry.variant not in _TILED_VARIANTS \
            or entry.backend != "pallas":
        return base
    if not _k_padding_matches(shape, base,
                              replace(base, block_k=entry.block_k)):
        return base
    return replace(base, block_m=entry.block_m, block_n=entry.block_n,
                   block_k=entry.block_k, source=source + "+tiles")


@functools.lru_cache(maxsize=4096)
def _prior_plan_cached(bucket: Tuple[int, int, int], w: int, m: int,
                       backend: str, exact: bool) -> Optional[ExecPlan]:
    """Memoized cost-model prior per bucketed key: a table miss would
    otherwise enumerate + rank the full candidate space at trace time for
    every GEMM call site.  Keyed on the bucketed shape (the same key the
    table uses); the returned plan is still re-validated against the real
    runtime shape in select_plan.  Safe across table swaps — the prior
    doesn't depend on table contents."""
    from repro.tune import space as tune_space
    return tune_space.prior_plan(bucket, w, m=m, backend=backend,
                                 exact=exact)


def _k_padding_matches(shape, base: ExecPlan, entry: ExecPlan) -> bool:
    """On the fp32-combine Pallas path the result depends on the *padded*
    contraction length: zero-padded K rows contribute centered digit planes
    and the ``z*z*kp`` correction term, which cancel exactly in real
    arithmetic but round differently in fp32 once accumulators pass 2**24.
    Bit-identity with the analytic default therefore requires the same
    padded K.  M/N padding is irrelevant (padded rows/cols are sliced away
    and never enter retained outputs), and exact-int plans equal the true
    product for any padding."""
    if entry.is_exact_int or entry.backend != "pallas":
        return True
    k = shape[1]
    return _padded(k, base.block_k) == _padded(k, entry.block_k)
