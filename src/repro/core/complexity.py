"""Operation-count complexity models — paper Eqs. (2)-(8), Fig. 5.

Counts are kept per (operation kind, bitwidth) so that the area model
(:mod:`repro.core.area`) and platform-specific cost models can weigh them;
``total_ops`` collapses to the paper's "arithmetic complexity" (Eqs. 6-8).

All recursions mirror the paper's equations exactly, including the bitwidth
bookkeeping of the ADD/SHIFT terms; closed forms (6)-(8) are leading-order
for n > 2 (exact at n = 2), which the tests check.
"""
from __future__ import annotations

import math
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, Tuple

Key = Tuple[str, int]  # (op kind, bitwidth)

MULT, ADD, ACCUM, SHIFT = "MULT", "ADD", "ACCUM", "SHIFT"


@dataclass
class OpCount:
    counts: Counter = field(default_factory=Counter)

    def add(self, kind: str, width: int, count: float) -> "OpCount":
        self.counts[(kind, width)] += count
        return self

    def __add__(self, other: "OpCount") -> "OpCount":
        out = OpCount(Counter(self.counts))
        out.counts.update(other.counts)
        return out

    def scaled(self, k: float) -> "OpCount":
        return OpCount(Counter({key: v * k for key, v in self.counts.items()}))

    def total(self, kinds=(MULT, ADD, ACCUM, SHIFT)) -> float:
        return sum(v for (kind, _), v in self.counts.items() if kind in kinds)

    def total_of(self, kind: str) -> float:
        return sum(v for (k, _), v in self.counts.items() if k == kind)

    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for (kind, _), v in self.counts.items():
            out[kind] = out.get(kind, 0.0) + v
        return out


def _ceil_half(w: int) -> int:
    return -(-w // 2)


def clog2(x: int) -> int:
    return max(int(math.ceil(math.log2(x))), 0) if x > 1 else 0


# ---------------------------------------------------------------------------
# Eq. (2): conventional n-digit MM.
# ---------------------------------------------------------------------------


def mm_complexity(n: int, w: int, d: int, *, w_a: int | None = None,
                  p: int | None = None) -> OpCount:
    """C(MM_n^[w]) for d x d matrices (Eq. 2).  ``p`` enables the Algorithm-5
    accumulation decomposition of Eq. (10) at the base case."""
    w_a = clog2(d) if w_a is None else w_a
    if n == 1:
        return _mm1_base(w, d, w_a, p)
    lo, hi = w // 2, _ceil_half(w)
    c = mm_complexity(n // 2, max(lo, 1), d, w_a=w_a, p=p)
    c = c + mm_complexity(n // 2, hi, d, w_a=w_a, p=p).scaled(3)
    c.add(ADD, w + w_a, d * d)
    c.add(ADD, 2 * w + w_a, 2 * d * d)
    c.add(SHIFT, w, d * d)
    c.add(SHIFT, hi, d * d)
    return c


def _mm1_base(w: int, d: int, w_a: int, p: int | None) -> OpCount:
    """Eq. (2b): d^3 (MULT^[w] + ACCUM^[2w]); ACCUM decomposed per Eq. (10)."""
    c = OpCount()
    c.add(MULT, w, d**3)
    if p is None:
        c.add(ACCUM, 2 * w + w_a, d**3)
    else:
        w_p = clog2(p)
        groups = d**3 / p
        c.add(ADD, 2 * w + w_p, groups * (p - 1))
        c.add(ADD, 2 * w + w_a, groups)
    return c


# ---------------------------------------------------------------------------
# Eq. (3): KSM scalar Karatsuba.
# ---------------------------------------------------------------------------


def ksm_complexity(n: int, w: int) -> OpCount:
    if n == 1:
        return OpCount().add(MULT, w, 1)
    lo, hi = w // 2, _ceil_half(w)
    c = ksm_complexity(n // 2, max(lo, 1))
    c = c + ksm_complexity(n // 2, hi + 1)
    c = c + ksm_complexity(n // 2, hi)
    c.add(ADD, 2 * w, 2)
    c.add(ADD, hi, 2)
    c.add(ADD, 2 * hi + 4, 2)
    c.add(SHIFT, w, 1)
    c.add(SHIFT, hi, 1)
    return c


# ---------------------------------------------------------------------------
# Eq. (4): KSMM — conventional matmul with KSM scalar products.
# ---------------------------------------------------------------------------


def ksmm_complexity(n: int, w: int, d: int, *, w_a: int | None = None,
                    p: int | None = None) -> OpCount:
    w_a = clog2(d) if w_a is None else w_a
    c = ksm_complexity(n, w).scaled(d**3)
    if p is None:
        c.add(ACCUM, 2 * w + w_a, d**3)
    else:
        w_p = clog2(p)
        groups = d**3 / p
        c.add(ADD, 2 * w + w_p, groups * (p - 1))
        c.add(ADD, 2 * w + w_a, groups)
    return c


# ---------------------------------------------------------------------------
# Eq. (5): KMM.
# ---------------------------------------------------------------------------


def kmm_complexity(n: int, w: int, d: int, *, w_a: int | None = None,
                   p: int | None = None) -> OpCount:
    w_a = clog2(d) if w_a is None else w_a
    if n == 1:
        return _mm1_base(w, d, w_a, p)
    lo, hi = w // 2, _ceil_half(w)
    c = kmm_complexity(n // 2, max(lo, 1), d, w_a=w_a, p=p)
    c = c + kmm_complexity(n // 2, hi + 1, d, w_a=w_a, p=p)
    c = c + kmm_complexity(n // 2, hi, d, w_a=w_a, p=p)
    c.add(ADD, 2 * hi + 4 + w_a, 2 * d * d)
    c.add(ADD, 2 * w + w_a, 2 * d * d)
    c.add(ADD, hi, 2 * d * d)
    c.add(SHIFT, w, d * d)
    c.add(SHIFT, hi, d * d)
    return c


# ---------------------------------------------------------------------------
# Eqs. (6)-(8): closed-form arithmetic complexity (leading order for n > 2).
# ---------------------------------------------------------------------------


def mm_arith(n: int, d: int) -> float:
    return 2 * n**2 * d**3 + 5 * (n / 2) ** 2 * d**2


def ksmm_arith(n: int, d: int) -> float:
    return (1 + 11 * (n / 2) ** math.log2(3)) * d**3


def kmm_arith(n: int, d: int) -> float:
    return (n / 2) ** math.log2(3) * (6 * d**3 + 8 * d**2)
