"""Tile-level Strassen decomposition composed over the digit-level KMM stack.

The paper's KMM algorithm cuts multiply work 3/4 per recursion level over
*bitwidth digits*; the same authors' Strassen multisystolic-array work
(arXiv 2502.10063) cuts spatial multiplies 7/8 per level over *(M, N, K)
tiles*.  The two recursions are orthogonal, so composing one level of each
is ~0.66x multiply work — this module implements the tile level and
delegates every sub-GEMM back through the production ``run_plan`` seam, so
a sub-product can itself be an XLA digit recursion or the fused single-pass
Pallas kernel.

Variant contract (``STRASSEN_VARIANTS``):

  * ``"strassen"``        — the 7 tile-products run on the analytic **XLA**
    exact plan at ``w + 1`` (a plain int32 dot in the MM1 window, the
    ``kmm_n``/``mm_n`` digit recursion with int32 combines above it).
  * ``"strassen+kmm2"``   — the 7 tile-products run on the **fused Pallas**
    kernel at ``w + 1`` with ``combine_int32=True``, inheriting the parent
    plan's tiles (the sub-problem is the half-shape, so parent tiles that
    fit the half-shape give each sub-GEMM the identical per-tile geometry
    as the full fused launch — exactly 7/8 of its grid steps).

Why ``w + 1``: Strassen's pre-additions (``A11 + A22`` etc.) grow operand
magnitude by one bit, so every sub-plan bound — the ``max_exact_k`` int32
headroom, the per-digit accumulator bound, the fused kernel's mode windows
— must be evaluated at ``w + 1`` on the half-K problem.
:func:`repro.tune.space.strassen_k_bound` composes those sub-bounds back
into a single full-problem K bound and ``validate`` gates every candidate
on it; within the bound no intermediate wraps and the result is the exact
integer product (asserted against the int64 oracle across the pruned
space and at the K-bound/K-bound+1 boundary by tests/test_strassen.py).

Odd-dimension padding contract: M, K and N are zero-padded to even before
the quadrant split and the output is sliced back.  Zero rows/columns
contribute exact zeros through every pre-add and sub-product (``split(0)``
handling lives inside the sub-plans, which already pad to their own tile
multiples), so padding never changes retained outputs.

This module deliberately imports only :mod:`repro.core.dispatch` — the
executor (:mod:`repro.kernels.ops`) passes ``run_plan`` in as the
``run_sub`` callable, keeping the dependency graph acyclic.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.dispatch import ExecPlan, analytic_plan

Array = jax.Array
Shape = Tuple[int, int, int]

STRASSEN_VARIANTS = ("strassen", "strassen+kmm2")


def strassen_sub_shape(shape: Shape) -> Shape:
    """(M, K, N) of each of the 7 sub-GEMMs: the even-padded halves."""
    m, k, n = shape
    return (-(-m // 2), -(-k // 2), -(-n // 2))


def strassen_sub_plan(plan: ExecPlan) -> ExecPlan:
    """The ExecPlan each of the 7 tile-products executes.

    Derived from the parent's *variant* alone (not its ``backend`` field:
    the backend-independent ``"strassen"`` variant is offered on both
    sweep backends, like ``xla_ref``).  Sub-operands are pre-added sums of
    w-bit tiles, hence ``w + 1``; combines stay int32 so the composition
    is exact end to end.
    """
    if plan.variant not in STRASSEN_VARIANTS:
        raise ValueError(f"not a strassen plan: {plan.variant!r}")
    w_sub = plan.w + 1
    if plan.variant == "strassen+kmm2":
        return ExecPlan("fused", w_sub, plan.m, backend="pallas",
                        block_m=plan.block_m, block_n=plan.block_n,
                        block_k=plan.block_k, combine_int32=True,
                        depth=0 if w_sub <= plan.m else 1,
                        source=plan.source)
    sub = analytic_plan(w_sub, plan.m, backend="xla", exact=True)
    if sub.variant == "mm1":
        # analytic_plan's MM1-window xla plan is the single int32 dot —
        # canonicalize to the variant name validate()/run_plan use for it.
        sub = replace(sub, variant="xla_ref", depth=0)
    return replace(sub, source=plan.source)


def _quadrants(x: Array):
    m2, k2 = x.shape[0] // 2, x.shape[1] // 2
    return (x[:m2, :k2], x[:m2, k2:], x[m2:, :k2], x[m2:, k2:])


def strassen_matmul(a: Array, b: Array, *, plan: ExecPlan,
                    run_sub: Callable[[Array, Array, ExecPlan], Array]
                    ) -> Array:
    """One Strassen level on (M, K) x (K, N) integer operands.

    The 7 products use Strassen's classical formulas; all pre-adds and the
    output combine are int32 ring arithmetic (exact as long as the final
    product fits int32, which ``tune.space.validate`` guarantees via the
    composed K bound).  ``run_sub(x, y, sub_plan)`` executes one
    sub-GEMM — the executor passes its own ``run_plan`` so sub-products
    ride the full dispatch stack (ref-kernel oracle mirroring included).
    """
    m_dim, k_dim = a.shape
    n_dim = b.shape[1]
    sub = strassen_sub_plan(plan)
    ai = a.astype(jnp.int32)
    bi = b.astype(jnp.int32)
    if (m_dim | k_dim) & 1:
        ai = jnp.pad(ai, ((0, m_dim & 1), (0, k_dim & 1)))
    if (k_dim | n_dim) & 1:
        bi = jnp.pad(bi, ((0, k_dim & 1), (0, n_dim & 1)))
    a11, a12, a21, a22 = _quadrants(ai)
    b11, b12, b21, b22 = _quadrants(bi)
    p1 = run_sub(a11 + a22, b11 + b22, sub)
    p2 = run_sub(a21 + a22, b11, sub)
    p3 = run_sub(a11, b12 - b22, sub)
    p4 = run_sub(a22, b21 - b11, sub)
    p5 = run_sub(a11 + a12, b22, sub)
    p6 = run_sub(a21 - a11, b11 + b12, sub)
    p7 = run_sub(a12 - a22, b21 + b22, sub)
    c11 = p1 + p4 - p5 + p7
    c12 = p3 + p5
    c21 = p2 + p4
    c22 = p1 - p2 + p3 + p6
    out = jnp.concatenate(
        [jnp.concatenate([c11, c12], axis=1),
         jnp.concatenate([c21, c22], axis=1)], axis=0)
    return out[:m_dim, :n_dim]
