"""Area-Unit (AU) circuit-area model — paper Eqs. (16)-(23), Fig. 12.

One AU = the area of a full adder.  Eq. (16): ADD^[w] = w AU,
FF^[w] = 0.7 w AU, MULT^[w] = w^2 AU.  The model reproduces the paper's
fixed-precision architecture comparison (MM1 vs KSMM vs KMM) including the
Algorithm-5 accumulator area reduction (Eq. 18) and the recursion-depth
selection used for Fig. 12.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.complexity import clog2

FF_RATIO = 19.5 / 28.0  # ~0.7: D-flip-flop transistors / full-adder transistors


def area_add(w: int) -> float:
    return float(w)


def area_ff(w: int) -> float:
    return FF_RATIO * w


def area_mult(w: int) -> float:
    return float(w) ** 2


def _ceil_half(w: int) -> int:
    return -(-w // 2)


def area_accum(w2: int, *, w_a: int, p: int = 4) -> float:
    """Per-accumulator area of a 2w-bit accumulation (w2 = 2w) under
    Algorithm 5 (Eq. 18): p accumulators share one wide adder+register."""
    w_p = clog2(p)
    total_p = (p - 1) * area_add(w2 + w_p) + area_add(w2 + w_a) + area_ff(w2 + w_a)
    return total_p / p


def area_mm1(w: int, *, x: int = 64, y: int = 64, p: int = 4) -> float:
    """Eq. (17): baseline MM1 MXU area."""
    w_a = clog2(x)
    per_pe = area_mult(w) + 3 * area_ff(w) + area_accum(2 * w, w_a=w_a, p=p)
    return x * y * per_pe


def area_ksm(n: int, w: int) -> float:
    """Eq. (21): recursive KSM multiplier area (c0 add free via concat)."""
    if n == 1:
        return area_mult(w)
    lo, hi = w // 2, _ceil_half(w)
    a = area_add(2 * w) + 2 * (area_add(2 * hi + 4) + area_add(hi))
    a += area_ksm(n // 2, max(lo, 1))
    a += area_ksm(n // 2, hi + 1)
    a += area_ksm(n // 2, hi)
    return a


def area_ksmm(n: int, w: int, *, x: int = 64, y: int = 64, p: int = 4) -> float:
    """Eq. (20): MM1 MXU with KSM multipliers in place of conventional ones."""
    w_a = clog2(x)
    per_pe = area_ksm(n, w) + 3 * area_ff(w) + area_accum(2 * w, w_a=w_a, p=p)
    return x * y * per_pe


def area_kmm(n: int, w: int, *, x: int = 64, y: int = 64, p: int = 4) -> float:
    """Eq. (22): KMM architecture area (3 sub-MXUs + pre/post adders)."""
    if n == 1:
        return area_mm1(w, x=x, y=y, p=p)
    w_a = clog2(x)
    lo, hi = w // 2, _ceil_half(w)
    a = 2 * x * area_add(hi)
    a += 2 * y * (area_add(2 * hi + 4 + w_a) + area_add(2 * w + w_a))
    a += area_kmm(n // 2, max(lo, 1), x=x, y=y, p=p)
    a += area_kmm(n // 2, hi + 1, x=x, y=y, p=p)
    a += area_kmm(n // 2, hi, x=x, y=y, p=p)
    return a


def best_kmm_levels(w: int, *, x: int = 64, y: int = 64, p: int = 4,
                    max_r: int = 4) -> int:
    """Fig. 12 rule: as many recursion levels as possible while still
    reducing area, minimum one level."""
    best_r, best_a = 1, area_kmm(2, w, x=x, y=y, p=p)
    for r in range(2, max_r + 1):
        a = area_kmm(2**r, w, x=x, y=y, p=p)
        if a < best_a:
            best_r, best_a = r, a
    return best_r


@dataclass(frozen=True)
class AuEfficiency:
    """Eq. (23) relative form: throughput/AU of ARCH over throughput/AU of
    MM1 (throughput roofs are equal for equal X/Y)."""

    arch: str
    w: int
    relative: float


def au_efficiency_vs_mm1(arch: str, w: int, *, n: int | None = None,
                         x: int = 64, y: int = 64, p: int = 4) -> AuEfficiency:
    base = area_mm1(w, x=x, y=y, p=p)
    if arch == "mm1":
        rel = 1.0
    elif arch == "ksmm":
        rel = base / area_ksmm(n or 2, w, x=x, y=y, p=p)
    elif arch == "kmm":
        r = int(math.log2(n)) if n else best_kmm_levels(w, x=x, y=y, p=p)
        rel = base / area_kmm(2**r, w, x=x, y=y, p=p)
    else:
        raise ValueError(f"unknown arch {arch!r}")
    return AuEfficiency(arch, w, rel)
