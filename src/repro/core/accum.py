"""Algorithm 5 — matmul with reduced accumulator complexity.

The paper mitigates KMM's accumulation penalty by pre-accumulating ``p``
products on a narrow ``2w + ceil(log2 p)``-bit adder before one add into the
wide ``2w + ceil(log2 d)``-bit running sum (Eq. 10), cutting wide adds and
accumulator registers by ``p`` (Fig. 6).

Tensor form: the contraction axis K is blocked into groups of ``p``; products
within a group reduce first (the narrow pre-sum), then group sums reduce into
the running accumulator.  The result is bit-identical to a flat accumulation;
what changes is the *hardware* cost, which :mod:`repro.core.complexity` and
:mod:`repro.core.area` account for, and which the Pallas kernel
(:mod:`repro.kernels.kmm_gemm`) realizes structurally with a per-K-tile
VMEM pre-accumulator.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

DEFAULT_P = 4  # the paper's evaluation setting


def preaccum_matmul(
    a: Array,
    b: Array,
    *,
    p: int = DEFAULT_P,
    accum_dtype=jnp.int32,
) -> Array:
    """Algorithm 5 on (..., M, K) x (K, N): two-level accumulation.

    K must be divisible by ``p`` (pad upstream otherwise).  Exactness bound:
    the pre-sum of ``p`` products of w-bit values needs ``2w + ceil(log2 p)``
    bits — int32 carriers keep this exact for the bitwidths the dispatch rule
    admits (w <= 14 with p <= 16).
    """
    m_axis, k = a.shape[:-1], a.shape[-1]
    if k % p:
        raise ValueError(f"K={k} not divisible by pre-accumulation p={p}")
    n = b.shape[-1]
    groups = k // p
    a_g = a.reshape(*m_axis, groups, p)
    b_g = b.reshape(groups, p, n)
    # Narrow pre-sum: contract only within each group of p.
    partial = lax.dot_general(
        a_g, b_g,
        dimension_numbers=(((a_g.ndim - 1,), (1,)), ((a_g.ndim - 2,), (0,))),
        preferred_element_type=accum_dtype,
    )  # (groups, ..., M, N)
    # Wide accumulation: one add per group into the running sum.
    return jnp.sum(partial, axis=0, dtype=accum_dtype)


def preaccum_mm1(p: int = DEFAULT_P, accum_dtype=jnp.int32):
    """Algorithm-5 base matmul usable as the ``mm1`` hook of Algorithms 3/4.

    Only plain (M, K) x (K, N) dimension numbers are supported — that is the
    shape the MXU tiles see.
    """

    def mm1(a: Array, b: Array, dims: lax.DotDimensionNumbers) -> Array:
        from repro.core.kmm import MATMUL_DIMS

        if dims != MATMUL_DIMS:
            return lax.dot_general(a, b, dims, preferred_element_type=accum_dtype)
        return preaccum_matmul(a, b, p=p, accum_dtype=accum_dtype)

    return mm1


def wide_adds_saved(k: int, p: int = DEFAULT_P) -> float:
    """Fraction of wide (2w + log2 d)-bit adds removed by Algorithm 5."""
    return 1.0 - (k // p) / k
