"""Core KMM library: the paper's algorithms, dispatch rule, and cost models."""
from repro.core.kmm import (
    digit_split,
    kmm_matmul,
    kmm_n,
    ksm_n,
    ksmm,
    matmul_dims_for,
    max_exact_k,
    mm_n,
    sm_n,
    MATMUL_DIMS,
    default_mm1,
)
from repro.core.accum import preaccum_matmul, preaccum_mm1, DEFAULT_P
from repro.core.dispatch import (Mode, Plan, ExecPlan, analytic_plan,
                                 numerics_fingerprint, select_mode,
                                 select_plan, efficiency_roof)

__all__ = [
    "digit_split", "kmm_matmul", "kmm_n", "ksm_n", "ksmm", "matmul_dims_for",
    "max_exact_k", "mm_n", "sm_n", "MATMUL_DIMS", "default_mm1",
    "preaccum_matmul", "preaccum_mm1", "DEFAULT_P",
    "Mode", "Plan", "ExecPlan", "analytic_plan", "numerics_fingerprint",
    "select_mode", "select_plan", "efficiency_roof",
]
