"""Karatsuba matrix multiplication (KMM) — tensor forms of Algorithms 1-4.

This module implements the paper's algorithm family on JAX arrays:

  * ``sm_n``   — Algorithm 1, conventional n-digit *scalar* multiplication
                 (elementwise over arrays).
  * ``ksm_n``  — Algorithm 2, n-digit Karatsuba scalar multiplication
                 (elementwise over arrays).
  * ``mm_n``   — Algorithm 3, conventional n-digit matrix multiplication
                 (4 digit-plane products per level).
  * ``kmm_n``  — Algorithm 4, n-digit Karatsuba matrix multiplication
                 (3 digit-plane products per level).
  * ``ksmm``   — KSM used elementwise inside a conventional matmul (the
                 paper's KSMM baseline, Section III-B.3).

Digit decomposition follows the paper exactly: a ``w``-bit integer ``x`` is
split at ``h = ceil(w/2)`` into ``x = x1 * 2**h + x0`` where ``x0`` is the
unsigned low ``h`` bits and ``x1`` the (possibly signed) high ``w - h`` bits.
For two's-complement integers carried in a wider dtype, the identity
``x == (x >> h) * 2**h + (x & (2**h - 1))`` holds for arbitrary sign, so the
algorithms below are exact for signed and unsigned inputs alike as long as the
carrier dtype does not overflow.

Hardware adaptation (see DESIGN.md §2): on TPU each digit-plane product is one
m-bit MXU pass.  On this CPU container digit planes are carried in int32 (or
int64 under ``jax.experimental.enable_x64``) with identical bit-exact
semantics.  ``max_exact_k`` gives the contraction-length bound below which the
int32 carrier is provably exact.

The base-case matmul (``MM_1`` in the paper, line 15/16 of Algorithms 3/4) is
injectable via the ``mm1`` argument so the same recursion drives the XLA
``dot_general`` path, the Algorithm-5 pre-accumulation path
(:mod:`repro.core.accum`), or the Pallas MXU kernels
(:mod:`repro.kernels.ops`).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array
# Base matmul: (lhs, rhs, dimension_numbers) -> product with int accumulation.
Mm1Fn = Callable[[Array, Array, lax.DotDimensionNumbers], Array]

# Canonical dimension numbers for a plain (M, K) x (K, N) matmul.
MATMUL_DIMS: lax.DotDimensionNumbers = (((1,), (0,)), ((), ()))


def default_mm1(accum_dtype=jnp.int32) -> Mm1Fn:
    """Base-case MM_1: a single dot_general with exact integer accumulation."""

    def mm1(a: Array, b: Array, dims: lax.DotDimensionNumbers) -> Array:
        return lax.dot_general(a, b, dims, preferred_element_type=accum_dtype)

    return mm1


def digit_split(x: Array, h: int) -> Tuple[Array, Array]:
    """Split integers into (high, low) digits at bit ``h``.

    ``low`` is the unsigned value of the low ``h`` bits; ``high`` is the
    arithmetically-shifted remainder, so ``x == (high << h) + low`` exactly
    in two's complement.
    """
    if h <= 0:
        raise ValueError(f"digit width must be positive, got {h}")
    mask = jnp.asarray((1 << h) - 1, dtype=x.dtype)
    lo = jnp.bitwise_and(x, mask)
    hi = jnp.right_shift(x, jnp.asarray(h, dtype=x.dtype))
    return hi, lo


def _shift_left(x: Array, s: int) -> Array:
    if jnp.issubdtype(x.dtype, jnp.integer):
        return jnp.left_shift(x, jnp.asarray(s, dtype=x.dtype))
    return x * jnp.asarray(2.0**s, dtype=x.dtype)


def _split_widths(w: int) -> Tuple[int, int, int]:
    """(w_hi, w_lo, h): bit widths of the high/low digits and the split point."""
    h = -(-w // 2)  # ceil(w/2)
    return w - h, h, h


# ---------------------------------------------------------------------------
# Algorithm 1 / 2 — scalar (elementwise) n-digit multiplication.
# ---------------------------------------------------------------------------


def sm_n(a: Array, b: Array, *, w: int, n: int) -> Array:
    """Algorithm 1: conventional n-digit scalar multiplication, elementwise."""
    _check_n(n)
    if n == 1:
        return a * b
    w_hi, w_lo, h = _split_widths(w)
    a1, a0 = digit_split(a, h)
    b1, b0 = digit_split(b, h)
    c1 = sm_n(a1, b1, w=max(w_hi, 1), n=n // 2)
    c10 = sm_n(a1, b0, w=w_lo, n=n // 2)
    c01 = sm_n(a0, b1, w=w_lo, n=n // 2)
    c0 = sm_n(a0, b0, w=w_lo, n=n // 2)
    c = _shift_left(c1, 2 * h)
    c = c + _shift_left(c10 + c01, h)
    return c + c0


def ksm_n(a: Array, b: Array, *, w: int, n: int) -> Array:
    """Algorithm 2: n-digit Karatsuba scalar multiplication, elementwise."""
    _check_n(n)
    if n == 1:
        return a * b
    w_hi, w_lo, h = _split_widths(w)
    a1, a0 = digit_split(a, h)
    b1, b0 = digit_split(b, h)
    a_s = a1 + a0
    b_s = b1 + b0
    c1 = ksm_n(a1, b1, w=max(w_hi, 1), n=n // 2)
    cs = ksm_n(a_s, b_s, w=w_lo + 1, n=n // 2)
    c0 = ksm_n(a0, b0, w=w_lo, n=n // 2)
    c = _shift_left(c1, 2 * h)
    c = c + _shift_left(cs - c1 - c0, h)
    return c + c0


# ---------------------------------------------------------------------------
# Algorithm 3 / 4 — n-digit matrix multiplication.
# ---------------------------------------------------------------------------


def mm_n(
    a: Array,
    b: Array,
    *,
    w: int,
    n: int,
    dimension_numbers: lax.DotDimensionNumbers = MATMUL_DIMS,
    mm1: Optional[Mm1Fn] = None,
    combine_dtype=None,
) -> Array:
    """Algorithm 3: conventional n-digit matrix multiplication (4 products)."""
    _check_n(n)
    mm1 = mm1 or default_mm1()
    if n == 1:
        out = mm1(a, b, dimension_numbers)
        return out if combine_dtype is None else out.astype(combine_dtype)
    w_hi, w_lo, h = _split_widths(w)
    a1, a0 = digit_split(a, h)
    b1, b0 = digit_split(b, h)
    kw = dict(dimension_numbers=dimension_numbers, mm1=mm1,
              combine_dtype=combine_dtype)
    c1 = mm_n(a1, b1, w=max(w_hi, 1), n=n // 2, **kw)
    c10 = mm_n(a1, b0, w=w_lo, n=n // 2, **kw)
    c01 = mm_n(a0, b1, w=w_lo, n=n // 2, **kw)
    c0 = mm_n(a0, b0, w=w_lo, n=n // 2, **kw)
    c = _shift_left(c1, 2 * h)
    c = c + _shift_left(c10 + c01, h)
    return c + c0


def kmm_n(
    a: Array,
    b: Array,
    *,
    w: int,
    n: int,
    dimension_numbers: lax.DotDimensionNumbers = MATMUL_DIMS,
    mm1: Optional[Mm1Fn] = None,
    combine_dtype=None,
) -> Array:
    """Algorithm 4: n-digit Karatsuba matrix multiplication (3 products).

    ``combine_dtype`` (optional) casts each digit-plane product before the
    shift-combine.  The TPU-faithful quantized path passes ``jnp.float32``
    here: every digit-plane product is an exact int32 MXU result and only the
    final recombination (which in the paper's hardware runs on wide
    accumulators that have no int32 TPU analogue) is carried in fp32 — see
    DESIGN.md §2.
    """
    _check_n(n)
    mm1 = mm1 or default_mm1()
    if n == 1:
        out = mm1(a, b, dimension_numbers)
        return out if combine_dtype is None else out.astype(combine_dtype)
    w_hi, w_lo, h = _split_widths(w)
    a1, a0 = digit_split(a, h)
    b1, b0 = digit_split(b, h)
    a_s = a1 + a0
    b_s = b1 + b0
    kw = dict(dimension_numbers=dimension_numbers, mm1=mm1,
              combine_dtype=combine_dtype)
    c1 = kmm_n(a1, b1, w=max(w_hi, 1), n=n // 2, **kw)
    cs = kmm_n(a_s, b_s, w=w_lo + 1, n=n // 2, **kw)
    c0 = kmm_n(a0, b0, w=w_lo, n=n // 2, **kw)
    c = _shift_left(c1, 2 * h)
    c = c + _shift_left(cs - c1 - c0, h)
    return c + c0


def ksmm(a: Array, b: Array, *, w: int, n: int) -> Array:
    """KSMM baseline: conventional matmul with KSM used per scalar product.

    Materializes the (M, K, N) product tensor, so use on small shapes only —
    it exists as the paper's comparison baseline (Section III-B.3), not as a
    production path.
    """
    prod = ksm_n(a[..., :, :, None], b[..., None, :, :], w=w, n=n)
    return prod.sum(axis=-2)


# ---------------------------------------------------------------------------
# Exactness bounds.
# ---------------------------------------------------------------------------


def max_exact_k(w: int, carrier_bits: int = 31) -> int:
    """Largest contraction length K for which an MM/KMM combine of unsigned
    ``w``-bit operands is exact in a signed ``carrier_bits+1``-bit carrier.

    Worst-case analysis (KMM, n=2, split at ``h = ceil(w/2)``): the shift
    combine ``c1<<2h + (cs - c1 - c0)<<h + c0`` is ring arithmetic — shifts,
    adds and subtracts are exact mod ``2**(carrier_bits+1)`` — so transient
    wrap-around in the intermediates cannot corrupt the result; exactness is
    governed solely by the *final recombined value* fitting the carrier.
    The Karatsuba middle branch is not the widest term: ``a1 + a0`` and
    ``b1 + b0`` are ``(h+1)``-bit digits, so ``cs <= K * (2**(h+1) - 2)**2
    ~ K * 2**(w+2)``, which is dominated by the recombined product
    ``K * (2**w - 1)**2 ~ K * 2**(2w)`` for every w >= 3.  The binding
    constraint is therefore ``2w + log2(K) <= carrier_bits``, i.e.
    ``K <= 2**(carrier_bits - 2w)``.  The true ceiling is
    ``floor((2**31 - 1) / (2**w - 1)**2)``; this power-of-two bound is a
    conservative under-approximation, and for ``w >= 11`` the two coincide:
    ``K = 2**(31-2w)`` all-max operands stay below ``2**31`` while ``K+1``
    overflows (for narrower ``w`` the ``(2**w - 1)`` slack leaves the true
    ceiling slightly higher — see
    ``test_max_exact_k_boundary_brute_force``).
    """
    head = carrier_bits - 2 * w
    return max(1 << head, 1) if head > 0 else 0


def _check_n(n: int) -> None:
    if n < 1 or (n & (n - 1)) != 0:
        raise ValueError(f"digit count n must be a positive power of two, got {n}")


# ---------------------------------------------------------------------------
# Convenience: einsum-style wrappers used by the quantized layers.
# ---------------------------------------------------------------------------


def matmul_dims_for(lhs_ndim: int, rhs_ndim: int) -> lax.DotDimensionNumbers:
    """dot_general dims contracting lhs[-1] with rhs[-2]; no batch dims."""
    return (((lhs_ndim - 1,), (rhs_ndim - 2,)), ((), ()))


@functools.partial(jax.jit, static_argnames=("w", "n", "combine_dtype"))
def kmm_matmul(a: Array, b: Array, w: int, n: int = 2, combine_dtype=None) -> Array:
    """jit'd KMM for stacked matrices: a[..., M, K] @ b[K, N] or [..., K, N]."""
    if b.ndim == 2:
        dims = matmul_dims_for(a.ndim, 2)
        return kmm_n(a, b, w=w, n=n, dimension_numbers=dims,
                     combine_dtype=combine_dtype)
    # Batched: match leading dims as batch.
    nbatch = b.ndim - 2
    dims = (
        ((a.ndim - 1,), (nbatch,)),
        (tuple(range(nbatch)), tuple(range(nbatch))),
    )
    return kmm_n(a, b, w=w, n=n, dimension_numbers=dims,
                 combine_dtype=combine_dtype)
