"""Multiplier compute efficiency — paper Eqs. (11)-(15), Fig. 11.

The metric (Eq. 12) measures *effective m-bit multiplications per instantiated
multiplier per clock cycle*: how much algebraic optimization an architecture
extracts from its area-dominant resource, independent of frequency and of the
executed bitwidth w.

    efficiency = (N_w_products * 4**r_conv) / (cycles * n_multipliers)

where ``N_w_products * 4**r_conv`` is the m-bit-mult count a conventional
algorithm (SM/MM) would need (Eq. 13) and ``cycles`` the measured/modeled
execution time in clock cycles.

Roofs: MM = 1 (Eq. 14), KMM = (4/3)**r (Eq. 15), FFIP = 2, FFIP+KMM =
2*(4/3)**r (Section V-B).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.dispatch import conv_mults_per_product, conv_recursion, select_mode


def roof(arch: str, w: int, m: int) -> float:
    """Fixed-precision efficiency roofs (Eqs. 14, 15 + FFIP variants)."""
    r = conv_recursion(w, m)
    if arch == "mm":
        return 1.0
    if arch == "kmm":
        return (4.0 / 3.0) ** r
    if arch == "ffip":
        return 2.0
    if arch == "ffip_kmm":
        return 2.0 * (4.0 / 3.0) ** r
    raise ValueError(f"unknown arch {arch!r}")


def precision_scalable_roof(arch: str, w: int, m: int) -> float:
    """Fig. 11: per-bitwidth roofs of the precision-scalable architectures.

    Both architectures spend `passes` tile reads per w-bit tile product; the
    conventional-algebra equivalent work is 4**r_conv m-bit passes.
    """
    conv = conv_mults_per_product(w, m)
    if arch == "kmm":
        passes = select_mode(w, m).passes
    elif arch == "mm":
        passes = 1 if w <= m else 4 ** conv_recursion(w, m)
    elif arch == "ffip":
        passes = (1 if w <= m else 4 ** conv_recursion(w, m)) / 2.0
    elif arch == "ffip_kmm":
        passes = select_mode(w, m).passes / 2.0
    else:
        raise ValueError(f"unknown arch {arch!r}")
    return conv / passes


@dataclass(frozen=True)
class Measured:
    """A measured/modeled execution for Eq. (12)."""

    n_w_products: float      # w-bit mults needed by conventional algebra
    w: int
    m: int
    cycles: float
    n_multipliers: int

    @property
    def efficiency(self) -> float:
        conv = self.n_w_products * conv_mults_per_product(self.w, self.m)
        return conv / (self.cycles * self.n_multipliers)


def gops(n_ops: float, seconds: float) -> float:
    return n_ops / seconds / 1e9
