"""ExecContext: one frozen bundle for "how do GEMMs execute here".

Before this module, the knobs that pick an execution path were scattered as
ad-hoc kwargs — ``backend=`` on :func:`repro.kernels.ops.int_gemm`,
``quant_backend=`` on the serve :class:`~repro.serve.engine.Engine`,
``tuning_table=`` in three places, ``mesh=`` in two, ``force_mode`` threaded
positionally through the ``custom_vjp`` entry points.  ``ExecContext`` is the
single replacement: a frozen dataclass carrying

  * ``backend``      — "xla" (plain dot_generals, GSPMD-partitionable) or
                       "pallas" (the fused single-pass KMM kernel);
  * ``mesh``         — a ``jax.sharding.Mesh`` when GEMMs should run
                       shard-mapped (see :mod:`repro.dist.shard_gemm`), or
                       None for single-device / ambient-GSPMD execution;
  * ``tuning_table`` — a :class:`repro.tune.TuningTable` (or a path to one)
                       consulted by plan selection, without mutating the
                       process-global registry;
  * ``force_mode``   — "auto" (the paper's dispatch rule) or "mm2" (the
                       conventional-baseline override used by benchmarks).

It is hashable (the table is excluded from eq/hash — tables are
numerics-pinned, so two contexts differing only in table compute identical
values) and is consumed at trace time, never inside traced computations.

Migration table (DESIGN.md §12):

    old kwarg                              new spelling
    -------------------------------------  --------------------------------
    quantized_matmul(..., backend="p")     quantized_matmul(..., context=ctx)
    quantized_matmul(..., force_mode="m")  ctx = ExecContext(force_mode="m")
    Engine(..., quant_backend="pallas")    Engine(..., context=ctx)
    Engine(..., tuning_table=path)         ctx = ExecContext(tuning_table=path)
    select_plan(..., backend=, table=)     select_plan(..., context=ctx)
    int_gemm(..., backend="pallas")        int_gemm(..., context=ctx)
    TrainConfig(tuning_table=path)         TrainConfig(context=ctx)

The old kwargs keep working through :func:`resolve_context` shims that emit
one ``DeprecationWarning`` naming every legacy kwarg used.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

__all__ = ["ExecContext", "resolve_context"]


@dataclass(frozen=True)
class ExecContext:
    """Execution context for the integer-GEMM stack (see module docstring).

    A context is *authoritative* where it is passed: ``Engine(context=ctx)``
    rewrites the model's quant policy to ``ctx.backend``/``ctx.force_mode``,
    and ``quantized_matmul(..., context=ctx)`` executes on ``ctx.backend``
    regardless of defaults.  Pass ``context=None`` (the default everywhere)
    to keep the call site's historical behaviour.
    """

    backend: str = "xla"            # "xla" | "pallas"
    mesh: Optional[Any] = None      # jax.sharding.Mesh | None
    # Excluded from eq/hash: TuningTable is a mutable dataclass, and tables
    # are numerics-pinned — they change speed, never values — so contexts
    # differing only in table are interchangeable as static/cache keys.
    tuning_table: Optional[Any] = field(default=None, compare=False)
    force_mode: str = "auto"        # "auto" | "mm2"

    def __post_init__(self):
        if self.backend not in ("xla", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"choices ('xla', 'pallas')")
        if self.force_mode not in ("auto", "mm2"):
            raise ValueError(f"unknown force_mode {self.force_mode!r}; "
                             f"choices ('auto', 'mm2')")

    # -- helpers ------------------------------------------------------------

    def replace(self, **kw) -> "ExecContext":
        return dataclasses.replace(self, **kw)

    def resolve_table(self):
        """The context's table as a loaded TuningTable (paths are loaded
        lazily, once per call — callers that care should pass the loaded
        object), or None."""
        if self.tuning_table is None:
            return None
        from repro.tune.table import TuningTable
        if isinstance(self.tuning_table, TuningTable):
            return self.tuning_table
        return TuningTable.load(self.tuning_table)

    def activate(self):
        """Context manager installing ``tuning_table`` into the process-global
        registry for the enclosed trace (no-op when the context carries no
        table — the currently active table, if any, stays in effect)."""
        if self.tuning_table is None:
            return contextlib.nullcontext()
        from repro.tune.table import use_table
        return use_table(self.tuning_table)

    def local_gemm_shape(self, shape: Tuple[int, int, int]
                         ) -> Tuple[int, int, int]:
        """Per-shard (M, K, N) of a GEMM under this context's mesh (the
        canonical serve sharding: M over data axes, N over model, K
        replicated).  Identity without a mesh."""
        if self.mesh is None:
            return shape
        from repro.tune.space import local_shape
        return local_shape(shape, self.mesh)


def resolve_context(context: Optional[ExecContext], *, what: str,
                    backend: Optional[str] = None,
                    force_mode: Optional[str] = None,
                    tuning_table: Optional[Any] = None,
                    mesh: Optional[Any] = None,
                    _defaults: Optional[ExecContext] = None) -> ExecContext:
    """Fold legacy kwargs into an :class:`ExecContext` (the deprecation shim).

    ``None`` legacy values mean "not passed".  Passing any legacy kwarg emits
    ONE ``DeprecationWarning`` listing all of them; passing legacy kwargs
    *and* ``context`` together is an error (ambiguous).  ``_defaults`` seeds
    the context the legacy values are folded into (callers with historical
    defaults other than ExecContext()'s pass them here).
    """
    legacy = {k: v for k, v in (("backend", backend),
                                ("force_mode", force_mode),
                                ("tuning_table", tuning_table),
                                ("mesh", mesh)) if v is not None}
    if context is not None:
        if legacy:
            raise TypeError(
                f"{what}: pass either context= or the deprecated "
                f"{sorted(legacy)} kwargs, not both")
        return context
    base = _defaults if _defaults is not None else ExecContext()
    if not legacy:
        return base
    warnings.warn(
        f"{what}: the {sorted(legacy)} kwarg(s) are deprecated; pass "
        f"context=repro.core.context.ExecContext(...) instead "
        f"(DESIGN.md §12 migration table)",
        DeprecationWarning, stacklevel=3)
    return base.replace(**legacy)
