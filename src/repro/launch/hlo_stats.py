"""Trip-count-aware cost extraction from post-SPMD compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
undercounts scan-based models by the trip count (verified: a scan of 10
matmuls reports the flops of one).  This module walks the HLO text instead:

  * builds a symbol table of every value's shape,
  * counts per-computation dot FLOPs (2 * prod(result) * prod(contracted)),
    HBM traffic (operand + result bytes of top-level ops; fused computations
    are charged at their fusion surface), and collective bytes by kind,
  * multiplies while-loop bodies by their trip count
    (``backend_config known_trip_count``, falling back to the loop-condition
    constant), recursively for nested loops.

Collective byte convention (per device): all-gather -> result bytes;
all-reduce / reduce-scatter / all-to-all / collective-permute -> operand
bytes.  Ring/tree factors are not modeled (first-order wire bytes).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
# name followed by a parameter list (params may nest tuples; we only need
# the name — callers also require "->" and "{" on the line).
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\），|while\(", re.UNICODE)

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dtype, dims = m.group(1), m.group(2)
    return dtype, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    whiles: List[Tuple[str, str, int]] = field(default_factory=list)
    # (body_name, cond_name, trips)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if not stripped:
            continue
        if not line.startswith(" ") and "->" in line and "{" in stripped:
            m = _COMP_HDR_RE.match(stripped.lstrip())
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps


def _build_symbols(text: str) -> Dict[str, str]:
    syms: Dict[str, str] = {}
    for line in text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            syms[m.group(1)] = m.group(2)
    return syms


def _trip_count(line: str, cond_lines: List[str]) -> int:
    m = re.search(r'known_trip_count[^\d]*(\d+)', line)
    if m:
        return int(m.group(1))
    consts = []
    for cl in cond_lines:
        mc = re.search(r"constant\((\d+)\)", cl)
        if mc:
            consts.append(int(mc.group(1)))
    return max(consts) if consts else 1


def _dot_flops(line: str, syms: Dict[str, str]) -> float:
    res = _shape_dims(line)
    if res is None:
        return 0.0
    _, rdims = res
    opnds = _OPND_RE.findall(line.split("dot(", 1)[1])
    if not opnds:
        return 0.0
    lhs_def = syms.get(opnds[0], "")
    lhs = _shape_dims(lhs_def)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if lhs is None or mc is None:
        return 0.0
    _, ldims = lhs
    contracted = 1
    for d in (mc.group(1).split(",") if mc.group(1) else []):
        di = int(d)
        if di < len(ldims):
            contracted *= ldims[di]
    out_elems = 1
    for d in rdims:
        out_elems *= d
    return 2.0 * out_elems * contracted


# Ops that touch only the sliced/updated/gathered region, not the full
# operand buffer — charge result (or update) bytes, not operand bytes.
_SLICING_OPS = ("dynamic-slice(", " slice(", "gather(")
_UPDATING_OPS = ("dynamic-update-slice(", "scatter(")
_RESULT_ONLY_OPS = ("broadcast(", "iota(", "constant(", "rng(",
                    "reshape(", "transpose(")


def _line_bytes(line: str, syms: Dict[str, str]) -> float:
    """HBM-traffic proxy for one top-level op.

    Default: result + operand bytes.  Slicing/gather ops read only the
    extracted region (result bytes x2); in-place updates (DUS/scatter) move
    ~2x the update operand; shape-only ops charge the result once.
    """
    m = _DEF_RE.match("  " + line) or _DEF_RE.match(line)
    if m is None:
        return 0.0
    rhs = m.group(2)
    result_bytes = _shape_elems_bytes(
        rhs[:rhs.find("(")] if "(" in rhs else rhs)
    if any(op in rhs for op in _SLICING_OPS):
        return 2.0 * result_bytes
    if any(op in rhs for op in _UPDATING_OPS):
        inner = rhs[rhs.find("("):]
        opnds = _OPND_RE.findall(inner)
        upd = _shape_elems_bytes(
            (syms.get(opnds[1], "") or "").split("(")[0]) if len(opnds) > 1 \
            else result_bytes
        return 2.0 * upd
    if any(op in rhs for op in _RESULT_ONLY_OPS):
        return float(result_bytes)
    total = result_bytes
    inner = rhs[rhs.find("("):] if "(" in rhs else ""
    for op in _OPND_RE.findall(inner):
        total += _shape_elems_bytes(
            (syms.get(op, "") or "").split("(")[0])
    return float(total)


_SKIP_BYTES_OPS = ("tuple(", "get-tuple-element(", "parameter(", "constant(",
                   "bitcast(", "after-all(", "iota(")


def parse_costs(text: str) -> Dict[str, float]:
    syms = _build_symbols(text)
    comps = _split_computations(text)
    costs: Dict[str, CompCost] = {}
    fused: set = set()
    for name, lines in comps.items():
        for line in lines:
            mf = re.search(r"calls=%?([\w.\-]+)", line)
            if mf and "fusion(" in line:
                fused.add(mf.group(1))

    for name, lines in comps.items():
        c = CompCost()
        for line in lines:
            if " dot(" in line:
                c.flops += _dot_flops(line, syms)
            for kind in _COLL_KINDS:
                if f" {kind}(" in line or f" {kind}-start(" in line:
                    if kind == "all-gather":
                        nbytes = _shape_elems_bytes(
                            line.split("=", 1)[1].split("all-gather")[0])
                    else:
                        inner = line[line.find("("):]
                        nbytes = sum(
                            _shape_elems_bytes((syms.get(o, "")).split("(")[0])
                            for o in _OPND_RE.findall(inner))
                    c.coll[kind] += nbytes
                    c.coll_count[kind] += 1
                    break
            if " while(" in line:
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                if mb:
                    cond_name = mc.group(1) if mc else ""
                    trips = _trip_count(line, comps.get(cond_name, []))
                    c.whiles.append((mb.group(1), cond_name, trips))
            if not any(sk in line for sk in _SKIP_BYTES_OPS):
                c.bytes += _line_bytes(line, syms)
        costs[name] = c

    memo: Dict[str, Tuple[float, float, Dict[str, float], Dict[str, float]]] = {}

    def total(name: str, depth: int = 0):
        if name in memo:
            return memo[name]
        c = costs.get(name)
        if c is None or depth > 32:
            return 0.0, 0.0, {}, {}
        f, b = c.flops, c.bytes
        coll = dict(c.coll)
        cnt = dict(c.coll_count)
        for body, cond, trips in c.whiles:
            bf, bb, bc, bn = total(body, depth + 1)
            f += trips * bf
            b += trips * bb
            for k, v in bc.items():
                coll[k] = coll.get(k, 0.0) + trips * v
            for k, v in bn.items():
                cnt[k] = cnt.get(k, 0.0) + trips * v
        memo[name] = (f, b, coll, cnt)
        return memo[name]

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line[len("ENTRY"):].strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        entry = max(costs, key=lambda n: costs[n].flops, default=None)
    f, b, coll, cnt = total(entry) if entry else (0.0, 0.0, {}, {})
    out = {"flops": f, "bytes": b,
           "total_bytes": float(sum(coll.values()))}
    for k, v in coll.items():
        out[f"{k}_bytes"] = v
    for k, v in cnt.items():
        out[f"{k}_count"] = v
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Back-compat wrapper: full trip-count-aware cost dictionary."""
    return parse_costs(hlo_text)
