import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
partitions, and compiles, and extract the roofline inputs.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. lowers + compiles the cell's step function against ShapeDtypeStruct
     inputs (no allocation),
  3. records ``compiled.memory_analysis()`` (proves it fits),
     ``compiled.cost_analysis()`` (FLOPs/bytes for the roofline), and the
     per-device collective bytes parsed from the post-SPMD HLO,
  4. writes one JSON per cell under --out (default experiments/dryrun/).

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every applicable cell,
                                                 # both meshes, subprocess
                                                 # isolation per cell
"""
import argparse
import json
import subprocess
import sys
import time
import traceback


def run_cell(arch: str, shape: str, mesh_kind: str, quant: str,
             out_dir: str, prequant: bool = False) -> dict:
    import jax
    from repro.configs import SHAPES, cell_applicable, get_config
    from repro.launch import steps
    from repro.launch.hlo_stats import collective_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.train import optim

    cfg = get_config(arch, quant=quant)
    cell = SHAPES[shape]
    qlabel = quant + ("+pq" if prequant else "")
    record = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "quant": qlabel,
        "n_devices": 512 if mesh_kind == "multi" else 256,
    }
    if not cell_applicable(cfg, shape):
        record.update(status="skipped",
                      reason="long_500k requires sub-quadratic decode "
                             "(see DESIGN.md §6)")
        return record
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ocfg = optim.AdamWConfig()
    specs = steps.input_specs(cfg, cell, mesh, ocfg, prequant=prequant)
    if cell.kind == "train":
        fn = steps.make_train_step(cfg, ocfg)
        args = (specs["params"], specs["opt_state"], specs["batch"])
        donate = (0, 1)
    elif cell.kind == "prefill":
        fn = steps.make_prefill_step(cfg)
        args = (specs["params"], specs["cache"], specs["batch"])
        donate = (1,)
    else:
        fn = steps.make_decode_step(cfg)
        args = (specs["params"], specs["cache"], specs["token"], specs["t"])
        if "mem" in specs:
            args = args + (specs["mem"],)
        donate = (1,)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    try:
        mem = compiled.memory_analysis()
    except Exception:   # some backends lack the C++ API; keep going
        mem = None
    try:
        cost = compiled.cost_analysis() or {}
    except Exception:
        cost = {}
    hlo = compiled.as_text()
    # Trip-count-aware walk (XLA's cost_analysis counts while bodies once —
    # see hlo_stats; the raw numbers are kept for reference as cost_xla).
    from repro.launch.hlo_stats import parse_costs
    full = parse_costs(hlo)
    _save_hlo(out_dir, arch, shape, mesh_kind, qlabel, hlo)
    record.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        cost={"flops": full.get("flops", 0.0),
              "bytes accessed": full.get("bytes", 0.0)},
        cost_xla={k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and k in
                  ("flops", "bytes accessed")},
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        },
        collectives={k: v for k, v in full.items()
                     if k.endswith("_bytes") or k.endswith("_count")},
        hlo_lines=hlo.count("\n"),
    )
    return record


def _save_hlo(out_dir, arch, shape, mesh_kind, quant, hlo: str) -> None:
    """Keep the post-SPMD HLO (zstd) so costs can be re-derived offline."""
    try:
        import zstandard as zstd

        path = _out_path(out_dir, arch, shape, mesh_kind, quant).replace(
            ".json", ".hlo.zst")
        with open(path, "wb") as f:
            f.write(zstd.ZstdCompressor(level=6).compress(hlo.encode()))
    except Exception:
        pass


def _out_path(out_dir, arch, shape, mesh_kind, quant):
    safe = arch.replace(".", "_")
    return os.path.join(out_dir, f"{safe}__{shape}__{mesh_kind}__{quant}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--quant", default="w12")
    ap.add_argument("--prequant", action="store_true",
                    help="serve cells use pre-quantized weight storage")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have a JSON")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        from repro.configs import SHAPES, list_archs
        failures = 0
        for arch in list_archs():
            for shape in SHAPES:
                for mesh_kind in ("single", "multi"):
                    path = _out_path(args.out, arch, shape, mesh_kind,
                                     args.quant)
                    if os.path.exists(path) and not args.force:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", mesh_kind, "--quant", args.quant,
                           "--out", args.out]
                    print(f"[dryrun] {arch} x {shape} x {mesh_kind}",
                          flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode:
                        failures += 1
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape required"
    qlabel = args.quant + ("+pq" if args.prequant else "")
    path = _out_path(args.out, args.arch, args.shape, args.mesh, qlabel)
    try:
        record = run_cell(args.arch, args.shape, args.mesh, args.quant,
                          args.out, prequant=args.prequant)
    except Exception as e:  # record the failure — it is a bug to fix
        record = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "quant": args.quant, "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(json.dumps({k: v for k, v in record.items()
                      if k not in ("traceback",)}, indent=1))
    return 0 if record.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
