"""Re-derive dry-run costs from saved .hlo.zst files (no recompilation).

    PYTHONPATH=src python -m repro.launch.reprocess [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    import zstandard as zstd

    from repro.launch.hlo_stats import parse_costs

    n = 0
    for jpath in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        hpath = jpath.replace(".json", ".hlo.zst")
        if not os.path.exists(hpath):
            continue
        with open(jpath) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        with open(hpath, "rb") as f:
            hlo = zstd.ZstdDecompressor().decompress(f.read()).decode()
        full = parse_costs(hlo)
        rec["cost"] = {"flops": full.get("flops", 0.0),
                       "bytes accessed": full.get("bytes", 0.0)}
        rec["collectives"] = {k: v for k, v in full.items()
                              if k.endswith("_bytes") or k.endswith("_count")}
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"reprocessed {n} records")


if __name__ == "__main__":
    main()
