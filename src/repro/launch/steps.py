"""Step builders shared by the train loop, serve engine, and dry-run.

``abstract_*`` helpers produce ShapeDtypeStructs (no allocation) with
NamedShardings attached, so ``jax.jit(step).lower(**specs)`` proves the
distribution config compiles for any (arch x shape x mesh) cell.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shard
from repro.models import lm
from repro.models.config import ModelConfig
from repro.train import optim
from repro.configs import ShapeCell

Params = Any


# ---------------------------------------------------------------------------
# Step functions.
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, ocfg: optim.AdamWConfig):
    """Train step with gradient-accumulation microbatching.

    ``cfg.n_microbatches`` splits the global batch: peak activation memory
    scales down by the factor while the optimizer sees the same mean
    gradient.  k=1 short-circuits to a single fused step.
    """
    k = max(cfg.n_microbatches, 1)

    def cast_params(params):
        """bf16 compute copy (see ModelConfig.bf16_cast_params).  The cast is
        elementwise on the sharded param, so downstream FSDP gathers move
        bf16; its VJP returns f32 grads."""
        if not cfg.bf16_cast_params:
            return params

        def leaf(path, p):
            name = str(getattr(path[-1], "key", path[-1]))
            if (p.dtype == jnp.float32 and p.ndim >= 2 and p.size > 65536
                    and name not in ("a_log", "u", "mix")):
                return p.astype(jnp.bfloat16)
            return p

        return jax.tree_util.tree_map_with_path(leaf, params)

    def loss_of(params, batch):
        return lm.loss_fn(cast_params(params), cfg, batch)

    def train_step(params, opt_state, batch):
        if k == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def mb(carry, mbatch):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mbatch)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                mb, (gzero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = lsum / k
        new_params, new_state, metrics = optim.update(ocfg, grads, opt_state,
                                                      params)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, token, t, mem=None):
        logits, new_cache = lm.decode_step(params, cfg, token, cache, t,
                                           mem=mem)
        return logits, new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, cache, batch):
        logits, new_cache, mem = lm.prefill(
            params, cfg, batch["tokens"], cache,
            frontend_embeds=batch.get("frontend_embeds"),
            enc_frames=batch.get("enc_frames"))
        return logits, new_cache, mem

    return prefill_step


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct + sharding).
# ---------------------------------------------------------------------------


def _sds(shape, dtype, mesh: Mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def abstract_params(cfg: ModelConfig, mesh: Mesh, prequant: bool = False):
    shapes = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    if prequant:
        from repro.quant.prequant import prequantize

        shapes = jax.eval_shape(lambda p: prequantize(p, cfg.quant), shapes)
    sh = shard.param_sharding(shapes, mesh)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shapes, sh)


def abstract_opt_state(params_abs, mesh: Mesh):
    def like(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding)

    mu = jax.tree.map(like, params_abs)
    nu = jax.tree.map(like, params_abs)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return optim.OptState(step=step, mu=mu, nu=nu)


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = cell.global_batch, cell.seq_len
    dp = shard.batch_spec(mesh)
    bspec = dp[0] if len(dp) else None
    txt = s - cfg.frontend_tokens if cfg.frontend == "vision" else s
    out = {
        "tokens": _sds((b, txt), jnp.int32, mesh, P(bspec)),
        "labels": _sds((b, txt), jnp.int32, mesh, P(bspec)),
        "mask": _sds((b, txt), jnp.float32, mesh, P(bspec)),
    }
    if cfg.frontend == "vision":
        out["frontend_embeds"] = _sds(
            (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32, mesh,
            P(bspec))
    if cfg.is_encdec:
        out["enc_frames"] = _sds((b, s, cfg.frontend_dim), jnp.float32, mesh,
                                 P(bspec))
    return out


def abstract_cache(cfg: ModelConfig, mesh: Mesh, batch: int, max_seq: int):
    shapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, max_seq))
    sh = shard.cache_sharding(shapes, mesh, batch=batch)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shapes, sh)


def abstract_mem(cfg: ModelConfig, mesh: Mesh, params_abs, batch: int,
                 enc_len: int):
    """Cross-attention memory specs for enc-dec decode."""
    if not cfg.is_encdec:
        return None
    ex = jax.ShapeDtypeStruct((batch, enc_len, cfg.d_model),
                              jnp.dtype(cfg.compute_dtype))
    shapes = jax.eval_shape(
        lambda p, e: lm._encdec_memory(p, cfg, e), params_abs, ex)
    dp = shard.batch_spec(mesh)
    bspec = dp[0] if len(dp) else None

    def rule(l):
        spec = [None] * len(l.shape)
        if len(l.shape) >= 2:
            spec[1] = bspec
        return jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=NamedSharding(mesh, P(*spec)))

    return jax.tree.map(rule, shapes)


def decode_token_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh):
    b = cell.global_batch
    dp = shard.batch_spec(mesh)
    bspec = dp[0] if len(dp) else None
    if b == 1:
        bspec = None
    token = _sds((b,), jnp.int32, mesh, P(bspec))
    t = jax.ShapeDtypeStruct((), jnp.int32,
                             sharding=NamedSharding(mesh, P()))
    return token, t


ENC_MEM_LEN = 4096  # cross-attention memory length for enc-dec decode cells


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                ocfg: Optional[optim.AdamWConfig] = None,
                prequant: bool = False) -> Dict[str, Any]:
    """All abstract inputs for the cell's step function."""
    params_abs = abstract_params(cfg, mesh,
                                 prequant=prequant and cell.kind != "train")
    if cell.kind == "train":
        ocfg = ocfg or optim.AdamWConfig()
        return {
            "params": params_abs,
            "opt_state": abstract_opt_state(params_abs, mesh),
            "batch": train_batch_specs(cfg, cell, mesh),
        }
    if cell.kind == "prefill":
        cache = abstract_cache(cfg, mesh, cell.global_batch, cell.seq_len)
        return {
            "params": params_abs,
            "cache": cache,
            "batch": train_batch_specs(cfg, cell, mesh),
        }
    # decode
    token, t = decode_token_specs(cfg, cell, mesh)
    out = {
        "params": params_abs,
        "cache": abstract_cache(cfg, mesh, cell.global_batch, cell.seq_len),
        "token": token,
        "t": t,
    }
    mem = abstract_mem(cfg, mesh, params_abs, cell.global_batch, ENC_MEM_LEN)
    if mem is not None:
        out["mem"] = mem
    return out
