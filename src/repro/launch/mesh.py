"""Production meshes.

Importing this module never touches jax device state; meshes are built
inside functions only.  The dry-run (and only the dry-run) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so these shapes are constructible on the CPU container.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None
              ) -> Mesh:
    """Arbitrary mesh for tests/small runs, e.g. make_mesh((2, 4))."""
    if axes is None:
        axes = ("data", "model") if len(shape) == 2 else \
               ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)


def single_device_mesh() -> Mesh:
    return jax.make_mesh((1, 1), ("data", "model"))
