"""Training launcher with restart supervision (fault tolerance).

    python -m repro.launch.train --arch llama3.2-1b --smoke --steps 50 \
        --ckpt-dir /tmp/ckpt --resume auto --max-restarts 2

``--max-restarts N`` supervises the training call: on an exception the
launcher reloads the latest checkpoint and continues (the crash-restart
path exercised by tests/test_train_loop.py).  ``--mesh dxm`` picks the mesh
(e.g. ``1x1`` for local smoke, ``16x16`` for the production pod).
"""
from __future__ import annotations

import argparse
import logging
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--quant", default="none",
                    choices=["none", "w8", "w12", "mixed"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--max-restarts", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tuning-table", default=None,
                    help="repro.tune table JSON (DESIGN.md §10)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.launch.mesh import make_mesh
    from repro.train import optim
    from repro.train.loop import TrainConfig, run_training

    cfg = get_config(args.arch, smoke=args.smoke, quant=args.quant)
    shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(shape)
    tc = TrainConfig(
        steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir if args.resume == "auto" else None,
        optimizer=optim.AdamWConfig(lr=args.lr, total_steps=args.steps),
        tuning_table=args.tuning_table,
    )
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, frontend=cfg.frontend,
        frontend_dim=cfg.frontend_dim, frontend_tokens=cfg.frontend_tokens,
        encdec=cfg.is_encdec)

    attempts = 0
    while True:
        try:
            result = run_training(cfg, mesh, tc, data_cfg)
            break
        except Exception as e:  # supervised restart
            attempts += 1
            logging.error("training failed (%s); restart %d/%d",
                          e, attempts, args.max_restarts)
            if attempts > args.max_restarts:
                raise
    final_loss = list(result.losses.values())[-1] if result.losses else None
    print(f"done: step={result.final_step} loss={final_loss} "
          f"resumed_from={result.restored_from} "
          f"stragglers={result.straggler_events}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
