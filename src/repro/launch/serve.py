"""Serving launcher: continuous-batching generation demo on a reduced config.

    python -m repro.launch.serve --arch gemma-2b --quant w12 --requests 8

With ``--poisson RATE`` the requests arrive as a Poisson process (RATE
requests/s) instead of all at once, so TTFT includes queueing delay.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--quant", default="w12",
                    choices=["none", "w8", "w12", "mixed"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", "--slots", dest="batch", type=int, default=4,
                    help="decode slots (continuous batching); decode runs "
                         "on the smallest power-of-two bucket covering the "
                         "live slots, so idle slots cost nothing")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: advance prompts this many tokens "
                         "per engine step, interleaved with decode "
                         "(power of two >= 8; 0: whole-prompt prefill at "
                         "admission)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share repeated prompt prefixes via paged-cache "
                         "snapshots (implies chunked prefill)")
    ap.add_argument("--eos", type=int, default=-1,
                    help="stop token id (-1: none)")
    ap.add_argument("--poisson", type=float, default=0.0,
                    help="arrival rate in req/s (0: all at once)")
    ap.add_argument("--full-size", action="store_true",
                    help="full config (needs real accelerators)")
    ap.add_argument("--tuning-table", default=None,
                    help="repro.tune table JSON (DESIGN.md §10)")
    ap.add_argument("--backend", "--quant-backend", dest="backend",
                    default="xla", choices=["xla", "pallas"],
                    help="quantized-GEMM backend: 'pallas' serves through "
                         "the fused single-pass kernel (DESIGN.md §11); "
                         "with --mesh it runs shard-mapped (DESIGN.md §12)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve sharded on a (data, model) mesh, e.g. 2x4 "
                         "(needs data*model visible devices)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable the repro.obs metrics registry and write a "
                         "JSON snapshot here after generation")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable the repro.obs span tracer and write a "
                         "Chrome-trace (chrome://tracing / Perfetto) JSON "
                         "file here after generation")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core.context import ExecContext
    from repro.models import lm
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    from repro.serve.engine import Engine, Request

    # Observability is opt-in: enable before engine construction so plan
    # selection / compile-time counters during warmup are captured too.
    if args.metrics_out:
        obs_metrics.enable()
    if args.trace_out:
        obs_trace.enable()

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(tuple(int(x) for x in args.mesh.split("x")))
    ctx = ExecContext(backend=args.backend, mesh=mesh,
                      tuning_table=args.tuning_table)
    cfg = get_config(args.arch, smoke=not args.full_size, quant=args.quant)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_seq=args.max_seq, batch_size=args.batch,
                    context=ctx,
                    prefill_chunk=args.prefill_chunk or None,
                    prefix_cache=args.prefix_cache)
    rng = np.random.default_rng(0)
    stop = (args.eos,) if args.eos >= 0 else ()
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             size=rng.integers(4, 17))),
                    max_new_tokens=args.max_new,
                    temperature=0.0 if i % 2 == 0 else 0.8,
                    stop_tokens=stop)
            for i in range(args.requests)]
    arrivals = None
    if args.poisson > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / args.poisson,
                                             size=len(reqs))).tolist()
    stats = engine.generate(reqs, arrival_s=arrivals)
    for i, r in enumerate(reqs):
        rs = r.stats
        print(f"req{i}: prompt[{len(r.prompt)}] -> {r.generated} "
              f"({rs.stop_reason}; ttft {rs.ttft_s*1e3:.0f}ms, "
              f"latency {rs.latency_s*1e3:.0f}ms)")
    print(f"prefill {stats.prefill_s:.2f}s; {stats.generated_tokens} tokens "
          f"in {stats.decode_steps} decode steps / {stats.decode_s:.2f}s "
          f"({stats.tokens_per_s:.1f} tok/s, occupancy "
          f"{stats.occupancy_pct:.0f}%, quant={args.quant}); "
          f"traces={engine.n_traces()}")
    if engine.prefix is not None:
        print(f"prefix cache: {engine.prefix.stats()}")
    if args.metrics_out:
        obs_metrics.write_snapshot(args.metrics_out)
        print(f"metrics snapshot -> {args.metrics_out}")
    if args.trace_out:
        obs_trace.export_chrome(args.trace_out)
        print(f"chrome trace -> {args.trace_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
