"""Serving launcher: batched generation demo on a reduced config.

    python -m repro.launch.serve --arch gemma-2b --quant w12 --requests 8
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--quant", default="w12",
                    choices=["none", "w8", "w12", "mixed"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--full-size", action="store_true",
                    help="full config (needs real accelerators)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import Engine, Request

    cfg = get_config(args.arch, smoke=not args.full_size, quant=args.quant)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_seq=args.max_seq, batch_size=args.batch)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             size=rng.integers(4, 17))),
                    max_new_tokens=args.max_new,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(args.requests)]
    stats = engine.generate(reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt[{len(r.prompt)}] -> {r.generated}")
    print(f"prefill {stats.prefill_s:.2f}s; decode {stats.decode_steps} steps "
          f"in {stats.decode_s:.2f}s ({stats.tokens_per_s:.1f} tok/s, "
          f"quant={args.quant})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
