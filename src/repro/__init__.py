"""repro: Karatsuba Matrix Multiplication (KMM) as a production JAX framework.

The paper's contribution lives in repro.core (algorithms + cost models),
repro.kernels (Pallas MXU kernels), and repro.quant (the precision-scalable
quantized execution path used by every model in repro.models).
"""
__version__ = "1.0.0"
