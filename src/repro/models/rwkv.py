"""RWKV-6 ("Finch") block: attention-free linear recurrence with
data-dependent per-channel decay.

Per head (state S in R^{D x D}):  S_t = diag(w_t) S_{t-1} + k_t^T v_t,
y_t = r_t (S_{t-1} + diag(u) k_t^T v_t).  The decay w_t is produced by a
low-rank MLP on the token-shifted input (the v6 data-dependence).  The
recurrence runs in fp32 (not an integer GEMM -> KMM inapplicable, DESIGN.md
§6); the r/k/v/g/o projections ride the quantized KMM path.

Implementation: time-step `lax.scan` for full sequences (state is
(B, H, D, D), so an associative scan over matrices would materialize
(B, S, H, D, D) — prohibitive); single-step update for decode, which is the
long_500k-relevant path (state size is sequence-length independent).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.quant.qmatmul import maybe_quantized_matmul
from repro.models.layers import norm_apply

Array = jax.Array
Params = Dict[str, Array]

LORA_DIM = 64


def rwkv_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    keys = jax.random.split(key, 10)
    s = d**-0.5
    hd = cfg.rwkv_head_dim
    nh = d // hd
    return {
        "mix": jnp.full((5, d), 0.5, jnp.float32),     # r,k,v,g,w shift mixes
        "wr": (jax.random.normal(keys[0], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(keys[1], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(keys[2], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(keys[3], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(keys[4], (d, d)) * s).astype(dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),       # base decay (slow)
        "w_lora_a": (jax.random.normal(keys[5], (d, LORA_DIM)) * s
                     ).astype(dtype),
        "w_lora_b": (jax.random.normal(keys[6], (LORA_DIM, d)) * LORA_DIM**-0.5
                     ).astype(dtype),
        "u": (jax.random.normal(keys[7], (nh, hd)) * 0.1).astype(jnp.float32),
        "ln_x": {"scale": jnp.ones((d,), jnp.float32),
                 "bias": jnp.zeros((d,), jnp.float32)},
    }


def _shift_mix(x: Array, prev: Array, mix: Array):
    """Token shift: blend each position with its predecessor.

    x: (B, S, d); prev: (B, 1, d) state carried across calls.
    Returns the 5 mixed streams (r, k, v, g, w) and the new shift state.
    """
    shifted = jnp.concatenate([prev, x[:, :-1, :]], axis=1)
    mixed = [x * m + shifted * (1.0 - m) for m in mix]  # 5 x (B,S,d)
    return mixed, x[:, -1:, :]


def _decay(p: Params, xw: Array) -> Array:
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(xw.dtype))
    lora = lora @ p["w_lora_b"].astype(xw.dtype)
    return jnp.exp(-jnp.exp(p["w0"] + lora.astype(jnp.float32)))  # (B,S,d) in (0,1)


def _project(p: Params, streams, quant, name: str, cfg):
    xr, xk, xv, xg, xw = streams
    r = maybe_quantized_matmul(xr, p["wr"], quant, f"{name}.wr")
    k = maybe_quantized_matmul(xk, p["wk"], quant, f"{name}.wk")
    v = maybe_quantized_matmul(xv, p["wv"], quant, f"{name}.wv")
    g = maybe_quantized_matmul(xg, p["wg"], quant, f"{name}.wg")
    w = _decay(p, xw)
    return r, k, v, g, w


def _heads(x: Array, nh: int, hd: int) -> Array:
    return x.reshape(*x.shape[:-1], nh, hd)


def rwkv_apply_stateful(p: Params, x: Array, cache: Optional[Params], cfg,
                        quant, name: str, mask: Optional[Array] = None,
                        last_idx: Optional[Array] = None
                        ) -> Tuple[Array, Params]:
    """Sequence forward from carried (shift, wkv) state; returns end state.

    Ragged prompts: ``mask`` (B, S) freezes the wkv state on pad positions
    (decay forced to 1, kv contribution zeroed) and zeroes pad inputs so the
    token shift at a left-pad boundary sees the same zeros an unpadded run
    starts from; ``last_idx`` (B,) picks each row's last *real* token for the
    carried shift state (right-padded prompts)."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    nh = d // hd
    if cache is None:
        cache = rwkv_cache_init(cfg, b, x.dtype)
    if mask is not None:
        x = jnp.where(mask[:, :, None], x, 0)
    prev = cache["shift"].astype(x.dtype)
    streams, new_shift = _shift_mix(x, prev, p["mix"])
    r, k, v, g, w = _project(p, streams, quant, name, cfg)
    r = _heads(r.astype(jnp.float32), nh, hd)
    k = _heads(k.astype(jnp.float32), nh, hd)
    v = _heads(v.astype(jnp.float32), nh, hd)
    w = _heads(w, nh, hd)                                  # (B,S,H,hd)
    if mask is not None:                                   # freeze on pads
        m4 = mask[:, :, None, None]
        k = jnp.where(m4, k, 0.0)
        w = jnp.where(m4, w, 1.0)
    if last_idx is not None:
        new_shift = jnp.take_along_axis(
            x, last_idx.astype(jnp.int32)[:, None, None], axis=1)
    u = p["u"]

    # Time-chunked scan: the matrix state (B, H, D, D) is carried across
    # chunks; inside a chunk the sequential recurrence runs under
    # jax.checkpoint so the backward stores only chunk-boundary states
    # (O(S/csz * state) instead of O(S * state)).
    csz = 64
    while s % csz:
        csz //= 2
    nc = s // csz

    def to_chunks(t):   # (B, S, H, hd) -> (nc, csz, B, H, hd)
        return jnp.moveaxis(t, 1, 0).reshape(nc, csz, b, nh, hd)

    xs = (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w))

    def step(state, xs_t):
        rt, kt, vt, wt = xs_t                                 # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]              # (B,H,hd,hd)
        yt = jnp.einsum("bhi,bhij->bhj", rt, state + u[None, :, :, None] * kv)
        new = wt[..., :, None] * state + kv
        return new, yt

    @jax.checkpoint
    def chunk_body(state, xs_chunk):
        return lax.scan(step, state, xs_chunk)

    sT, y = lax.scan(chunk_body, cache["wkv"], xs)            # (nc,csz,B,H,hd)
    y = jnp.moveaxis(y.reshape(s, b, nh, hd), 0, 1).reshape(b, s, d)
    y = norm_apply(p["ln_x"], y, kind="ln")
    y = y * jax.nn.silu(g.astype(jnp.float32))
    out = maybe_quantized_matmul(y.astype(x.dtype), p["wo"], quant,
                                 f"{name}.wo")
    return out, {"shift": new_shift.astype(cache["shift"].dtype), "wkv": sT}


def rwkv_apply(p: Params, x: Array, cfg, quant, name: str) -> Array:
    """Full-sequence forward (train)."""
    out, _ = rwkv_apply_stateful(p, x, None, cfg, quant, name)
    return out


def rwkv_cache_init(cfg, batch: int, dtype) -> Params:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    return {
        "shift": jnp.zeros((batch, 1, d), dtype),
        "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
    }


def rwkv_decode(p: Params, x: Array, cache: Params, cfg, quant,
                name: str) -> Tuple[Array, Params]:
    """Single-token step: x (B, 1, d); constant-size state."""
    b, _, d = x.shape
    hd = cfg.rwkv_head_dim
    nh = d // hd
    streams, new_shift = _shift_mix(x, cache["shift"].astype(x.dtype),
                                    p["mix"])
    r, k, v, g, w = _project(p, streams, quant, name, cfg)
    rt = _heads(r.astype(jnp.float32)[:, 0], nh, hd)
    kt = _heads(k.astype(jnp.float32)[:, 0], nh, hd)
    vt = _heads(v.astype(jnp.float32)[:, 0], nh, hd)
    wt = _heads(w[:, 0], nh, hd)
    kv = kt[..., :, None] * vt[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", rt,
                   cache["wkv"] + p["u"][None, :, :, None] * kv)
    new_state = wt[..., :, None] * cache["wkv"] + kv
    y = y.reshape(b, 1, d)
    y = norm_apply(p["ln_x"], y, kind="ln")
    y = y * jax.nn.silu(g.astype(jnp.float32))
    out = maybe_quantized_matmul(y.astype(x.dtype), p["wo"], quant,
                                 f"{name}.wo")
    return out, {"shift": new_shift.astype(cache["shift"].dtype),
                 "wkv": new_state}
