"""Mamba (selective SSM) block — jamba's recurrent layer.

Hardware/algorithm note (DESIGN.md §6): the selective-scan recurrence
``h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t`` has data-dependent diagonal decay
and is computed in fp32 — it is not an integer GEMM, so the paper's KMM does
not apply to it; the block's projections (in/out/x/dt) do ride the quantized
KMM path.  Prefill uses a chunked associative scan (O(chunk * d_inner *
d_state) peak memory); decode is a single-step state update.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.quant.qmatmul import maybe_quantized_matmul

Array = jax.Array
Params = Dict[str, Array]


# Default associative-scan chunk for the full-sequence (train) path.  Serve
# prefill passes its own fixed grid (lm.SSM_PREFILL_GRID) so that chunked
# prefill brackets the fp32 recurrence identically to single-shot.
MAMBA_SCAN_CHUNK = 128


def _dt_rank(d_model: int) -> int:
    return max(1, -(-d_model // 16))


def mamba_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    di = cfg.expand * d
    ds, cw = cfg.d_state, cfg.conv_width
    dtr = _dt_rank(d)
    keys = jax.random.split(key, 6)
    s = d**-0.5
    return {
        "in_proj": (jax.random.normal(keys[0], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (cw, di)) * cw**-0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(keys[2], (di, dtr + 2 * ds)) * di**-0.5
                   ).astype(dtype),
        "dt_proj": (jax.random.normal(keys[3], (dtr, di)) * dtr**-0.5
                    ).astype(dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(keys[4], (di, d)) * di**-0.5
                     ).astype(dtype),
    }


def _ssm_inputs(p: Params, x: Array, cfg, quant, name: str,
                conv_tail: Optional[Array] = None,
                mask: Optional[Array] = None):
    """Projections + causal depthwise conv; returns
    (x_conv, z, delta, B, C, x_in).

    ``conv_tail``: the previous chunk's last conv_width-1 pre-conv inputs
    (zeros at sequence start).  ``mask`` (B, S): pad positions get zeroed
    pre-conv inputs, so conv windows spanning a ragged-prompt boundary see
    exactly the zeros an unpadded run would.  ``x_in`` (the masked pre-conv
    projection) is returned so the caller can carry the conv tail across
    chunk boundaries without re-projecting."""
    di = cfg.expand * cfg.d_model
    ds = cfg.d_state
    dtr = _dt_rank(cfg.d_model)
    xz = maybe_quantized_matmul(x, p["in_proj"], quant, f"{name}.in_proj")
    x_in, z = jnp.split(xz, 2, axis=-1)
    if mask is not None:
        x_in = jnp.where(mask[:, :, None], x_in, 0)
    if conv_tail is None:
        x_pad = jnp.pad(x_in, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([conv_tail.astype(x_in.dtype), x_in], axis=1)
    x_conv = _causal_conv(x_pad, p["conv_w"], p["conv_b"])
    x_conv = jax.nn.silu(x_conv)
    x_dbl = maybe_quantized_matmul(x_conv, p["x_proj"], quant, f"{name}.x_proj")
    dt_r, b_mat, c_mat = jnp.split(x_dbl, [dtr, dtr + ds], axis=-1)
    delta = maybe_quantized_matmul(dt_r, p["dt_proj"], quant, f"{name}.dt_proj")
    delta = jax.nn.softplus(delta.astype(jnp.float32) + p["dt_bias"])
    return (x_conv, z, delta, b_mat.astype(jnp.float32),
            c_mat.astype(jnp.float32), x_in)


def _causal_conv(x_padded: Array, w: Array, b: Array) -> Array:
    """Depthwise causal 1D conv; x_padded (B, S + cw - 1, di), w (cw, di)."""
    cw = w.shape[0]
    out = jnp.zeros_like(x_padded[:, cw - 1:, :])
    for i in range(cw):
        tap = x_padded[:, i:i + out.shape[1], :]
        out = out + tap * w[i][None, None, :]
    return out + b[None, None, :]


def mamba_apply_stateful(p: Params, x: Array, cache: Optional[Params], cfg,
                         quant, name: str, chunk: int = 128,
                         mask: Optional[Array] = None,
                         last_idx: Optional[Array] = None
                         ) -> Tuple[Array, Params]:
    """Sequence forward from a carried (conv, ssm) state; returns the state
    after the last position (chunked-prefill building block).

    Ragged prompts: ``mask`` (B, S) freezes the recurrence on pad positions
    (h_t = h_{t-1}) and zeroes their conv inputs, and ``last_idx`` (B,)
    makes the carried conv tail end at each row's last *real* token instead
    of the last padded position — so the returned state matches a per-row
    unpadded run exactly.

    The carried conv tail is sliced from the concatenation of the incoming
    tail and this chunk's (masked) pre-conv inputs, so a tail window that
    reaches past the chunk start picks up the *previous* chunk's inputs —
    resume-from-offset prefill (chunk boundaries anywhere, including a
    final chunk shorter than conv_width-1) stays exact."""
    b, s, _ = x.shape
    di, ds = cfg.expand * cfg.d_model, cfg.d_state
    cw = cfg.conv_width
    if cache is None:
        cache = mamba_cache_init(cfg, b, x.dtype)
    x_conv, z, delta, b_mat, c_mat, x_in = _ssm_inputs(
        p, x, cfg, quant, name, conv_tail=cache["conv"], mask=mask)
    a = -jnp.exp(p["a_log"])                                 # (di, ds)
    x_f = x_conv.astype(jnp.float32)

    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    @jax.checkpoint   # recompute dA/dBx per chunk in bwd
    def per_chunk(h0, idx):
        sl = lambda t: lax.dynamic_slice_in_dim(t, idx * chunk, chunk, axis=1)
        d_c, b_c, c_c, x_c = sl(delta), sl(b_mat), sl(c_mat), sl(x_f)
        da = jnp.exp(d_c[..., None] * a[None, None])          # (B,c,di,ds)
        dbx = (d_c * x_c)[..., None] * b_c[:, :, None, :]     # (B,c,di,ds)
        if mask is not None:                                  # freeze on pads
            m_c = sl(mask)[..., None, None]
            da = jnp.where(m_c, da, 1.0)
            dbx = jnp.where(m_c, dbx, 0.0)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        aprod, bsum = lax.associative_scan(combine, (da, dbx), axis=1)
        h_all = aprod * h0[:, None] + bsum                    # (B,c,di,ds)
        y_c = jnp.einsum("bcds,bcs->bcd", h_all, c_c)
        return h_all[:, -1], y_c

    hT, y = lax.scan(per_chunk, cache["ssm"], jnp.arange(nc))
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, di)
    y = y + x_f * p["d_skip"][None, None, :]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = maybe_quantized_matmul(y, p["out_proj"], quant, f"{name}.out_proj")
    # Conv tail for the next chunk: the last cw-1 pre-conv inputs ending at
    # each row's last real token.  Slicing the concat of the incoming tail
    # and this chunk's x_in means windows reaching below the chunk start
    # fall through to the previous chunk's inputs (zeros at sequence start),
    # exactly as an unchunked run would see them.
    full = jnp.concatenate([cache["conv"].astype(x_in.dtype), x_in], axis=1)
    if last_idx is None:
        tail = full[:, s:, :]
    else:
        # window ends at x_in[last_idx] == full[cw-1+last_idx]
        tail = jax.vmap(
            lambda xr, st: lax.dynamic_slice_in_dim(xr, st, cw - 1, axis=0)
        )(full, last_idx.astype(jnp.int32) + 1)
    return out, {"conv": tail.astype(cache["conv"].dtype), "ssm": hT}


def mamba_apply(p: Params, x: Array, cfg, quant, name: str,
                chunk: int = 128) -> Array:
    """Full-sequence (train) forward via chunked associative scan."""
    out, _ = mamba_apply_stateful(p, x, None, cfg, quant, name, chunk=chunk)
    return out


def mamba_cache_init(cfg, batch: int, dtype) -> Params:
    di = cfg.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.d_state), jnp.float32),
    }


def mamba_decode(p: Params, x: Array, cache: Params, cfg, quant,
                 name: str) -> Tuple[Array, Params]:
    """Single-token step: x (B, 1, d)."""
    b = x.shape[0]
    di, ds = cfg.expand * cfg.d_model, cfg.d_state
    xz = maybe_quantized_matmul(x, p["in_proj"], quant, f"{name}.in_proj")
    x_in, z = jnp.split(xz, 2, axis=-1)                       # (B,1,di)
    window = jnp.concatenate([cache["conv"], x_in.astype(cache["conv"].dtype)],
                             axis=1)                          # (B,cw,di)
    x_conv = (window * p["conv_w"][None]).sum(axis=1, keepdims=True)
    x_conv = jax.nn.silu(x_conv + p["conv_b"][None, None, :])
    dtr = _dt_rank(cfg.d_model)
    x_dbl = maybe_quantized_matmul(x_conv, p["x_proj"], quant, f"{name}.x_proj")
    dt_r, b_mat, c_mat = jnp.split(x_dbl, [dtr, dtr + ds], axis=-1)
    delta = maybe_quantized_matmul(dt_r, p["dt_proj"], quant, f"{name}.dt_proj")
    delta = jax.nn.softplus(delta.astype(jnp.float32) + p["dt_bias"])  # (B,1,di)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(delta[..., None] * a[None, None])            # (B,1,di,ds)
    dbx = (delta * x_conv.astype(jnp.float32))[..., None] * \
        b_mat.astype(jnp.float32)[:, :, None, :]
    h = cache["ssm"] * da[:, 0] + dbx[:, 0]                   # (B,di,ds)
    y = jnp.einsum("bds,bs->bd", h, c_mat.astype(jnp.float32)[:, 0])
    y = y + x_conv.astype(jnp.float32)[:, 0] * p["d_skip"][None, :]
    y = (y * jax.nn.silu(z.astype(jnp.float32)[:, 0]))[:, None, :]
    out = maybe_quantized_matmul(y.astype(x.dtype), p["out_proj"], quant,
                                 f"{name}.out_proj")
    return out, {"conv": window[:, 1:], "ssm": h}
