"""Model configuration: a layer-pattern description of every assigned arch.

A model is ``n_periods`` repetitions of a ``pattern`` of blocks; parameters
are stacked over periods and the forward pass scans over them, keeping HLO
size O(len(pattern)) regardless of depth.  Dense transformers have a
single-block pattern; jamba's 1:7 mamba:attention interleave (with MoE every
other layer) is one 8-block pattern scanned 4x.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

from repro.quant.policy import QuantConfig


@dataclass(frozen=True)
class Block:
    kind: str = "attn"        # "attn" | "mamba" | "rwkv"
    moe: bool = False         # MoE MLP instead of dense MLP


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[Block, ...]
    n_periods: int
    act: str = "silu"                # silu | gelu | relu2
    glu: bool = True                 # gated MLP (SwiGLU/GeGLU); False: plain
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba)
    d_state: int = 16
    conv_width: int = 4
    expand: int = 2
    # RWKV
    rwkv_head_dim: int = 64
    # Encoder-decoder
    encoder_periods: int = 0         # >0 => enc-dec; encoder uses `pattern`
    # Modality frontend stub ("none" | "vision" | "audio")
    frontend: str = "none"
    frontend_dim: int = 0            # embedding dim provided by the stub
    frontend_tokens: int = 0         # prefix tokens contributed at prefill
    # Quantized execution (the paper's KMM integer GEMM path)
    quant: QuantConfig = QuantConfig()
    # Numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # Training
    remat: bool = True
    # Gradient-accumulation microbatches for the full-size train shape:
    # period-boundary remat residuals scale with n_periods * B/k * S * d, so
    # deep/wide archs split the global batch to fit 16 GB/chip.
    n_microbatches: int = 1
    # Cast fp32 weight matrices to bf16 before use in the train step: FSDP
    # all-gathers and TP partial-sum reductions then move bf16, halving the
    # dominant collective bytes (§Perf).  f32 master params stay in the
    # optimizer; gradients accumulate in f32.
    bf16_cast_params: bool = True

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.n_periods

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a multiple of 512 so the vocab dim
        shards on any production mesh axis (Megatron-style vocab padding).
        Logits beyond ``vocab_size`` are masked to -inf in the head."""
        return -(-self.vocab_size // 512) * 512

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_periods > 0

    @property
    def attn_free(self) -> bool:
        return all(b.kind != "attn" for b in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch admits long-context (500k) execution: decode cost
        per token must not require materializing quadratic state growth —
        SSM/linear-recurrence or hybrid archs qualify."""
        return any(b.kind in ("mamba", "rwkv") for b in self.pattern)

    def with_quant(self, quant: QuantConfig) -> "ModelConfig":
        return replace(self, quant=quant)

    def scaled_down(self, **kw) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests."""
        return replace(self, **kw)


def dense_pattern(n_layers: int) -> Tuple[Tuple[Block, ...], int]:
    return (Block("attn"),), n_layers


def moe_pattern(n_layers: int) -> Tuple[Tuple[Block, ...], int]:
    return (Block("attn", moe=True),), n_layers


def count_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (used by roofline MODEL_FLOPS)."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    total = v * d  # embedding
    if not cfg.tie_embeddings:
        total += v * d
    for blk in cfg.pattern:
        n = cfg.n_periods
        if blk.kind == "attn":
            total += n * d * (cfg.q_dim + 2 * cfg.kv_dim) + n * cfg.q_dim * d
        elif blk.kind == "mamba":
            di = cfg.expand * d
            total += n * (d * 2 * di + di * cfg.conv_width
                          + di * (cfg.d_state * 2 + 1 + d)
                          + di * (d // 16 if d >= 16 else 1))
        elif blk.kind == "rwkv":
            total += n * (d * d * 5 + d * d)  # r,k,v,g,w (low-rank approx) + out
        if blk.moe:
            fe = cfg.d_ff_expert or ff
            mults = 3 if cfg.glu else 2
            total += n * (cfg.n_experts * mults * d * fe + d * cfg.n_experts)
        else:
            mults = 3 if cfg.glu else 2
            total += n * mults * d * ff
    if cfg.encoder_periods:
        # encoder stack mirrors the pattern with encoder_periods repeats
        total += int(total * cfg.encoder_periods / max(cfg.n_periods, 1) * 0.5)
    return int(total)


def count_active_params(cfg: ModelConfig) -> int:
    """Active (per-token) parameters — MoE counts only top_k experts."""
    if not cfg.n_experts:
        return count_params(cfg)
    full = count_params(cfg)
    fe = cfg.d_ff_expert or cfg.d_ff
    mults = 3 if cfg.glu else 2
    moe_blocks = sum(1 for b in cfg.pattern if b.moe) * cfg.n_periods
    all_experts = moe_blocks * cfg.n_experts * mults * cfg.d_model * fe
    active_experts = moe_blocks * cfg.top_k * mults * cfg.d_model * fe
    return int(full - all_experts + active_experts)
