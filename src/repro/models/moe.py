"""Mixture-of-Experts layer with per-sequence sort-based capacity dispatch.

Routing, gather and combine are vmapped over the batch dimension, so under
pjit every dispatch step is *local to the data shard* (no global argsort or
cross-device gathers); only the expert GEMM itself crosses shards — expert
weights are sharded over the 'model' axis (expert parallelism) and XLA
inserts the EP all-to-all when it resharded the (E, B*C, d) buffer.

Capacity is per sequence (C = S*top_k*factor/E, floor 8, rounded to 8);
overflow drops ride the residual.  Expert GEMMs run through the quantized
KMM path (`quantized_matmul_batched`) like every other matmul — and the
dispatch is *ragged*: the per-(batch, expert) live token counts computed
during the sort ride along as a traced (E, B) operand with ``seg = cap``,
so the fused grouped kernel masks the zero-padded capacity tail exactly
and skips fully-dead m-blocks instead of multiplying zeros.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.quant.qmatmul import maybe_quantized_batched, maybe_quantized_matmul
from repro.models.layers import _act

Array = jax.Array
Params = Dict[str, Array]

# Dispatch observability: tokens routed per (expert slot occupancy) and
# capacity-overflow drops.  Observed via jax.debug.callback only when the
# metrics layer is enabled at trace time — zero overhead otherwise.
_TOKENS_PER_EXPERT = obs_metrics.histogram(
    "repro_moe_tokens_per_expert",
    "live (post-capacity) tokens per expert per dispatch, by layer",
    labels=("layer",),
    buckets=(0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0,
             512.0, 1024.0))
_DROPPED_TOKENS = obs_metrics.counter(
    "repro_moe_dropped_tokens_total",
    "token->expert assignments dropped by the capacity bound, by layer",
    labels=("layer",))


def _observe_dispatch(name: str, live_counts, dropped) -> None:
    for c in np.asarray(live_counts).reshape(-1):
        _TOKENS_PER_EXPERT.observe(float(c), name)
    _DROPPED_TOKENS.inc(name, by=float(np.asarray(dropped)))


def moe_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    fe = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s_in, s_out = d**-0.5, fe**-0.5
    p = {
        "router": (jax.random.normal(kr, (d, e)) * s_in).astype(jnp.float32),
        "wi": (jax.random.normal(k1, (e, d, fe)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (e, fe, d)) * s_out).astype(dtype),
    }
    if cfg.glu:
        p["wg"] = (jax.random.normal(k2, (e, d, fe)) * s_in).astype(dtype)
    return p


def _capacity(tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    cap = int(tokens * top_k * factor / n_experts)
    return max(8, -(-cap // 8) * 8)


def moe_apply(p: Params, x: Array, cfg, quant, name: str) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, k, e, cfg.capacity_factor)

    logits = maybe_quantized_matmul(
        x.astype(jnp.float32), p["router"], quant, f"{name}.router")
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (B, S, E)

    def dispatch_one(xf, pr):
        """xf: (S, d); pr: (S, E) -> buf (E, C, d), live counts + aux."""
        gate_vals, expert_ids = jax.lax.top_k(pr, k)              # (S, k)
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True),
                                         1e-9)
        flat_e = expert_ids.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
        flat_g = gate_vals.reshape(-1)
        order = jnp.argsort(flat_e)
        se = flat_e[order]
        st = flat_t[order]
        sg = flat_g[order]
        bounds = jnp.searchsorted(se, jnp.arange(e + 1, dtype=jnp.int32))
        group_start = bounds[:-1]
        sizes = (bounds[1:] - bounds[:-1]).astype(jnp.int32)      # (E,)
        live = jnp.minimum(sizes, cap)                            # (E,)
        rank = jnp.arange(s * k, dtype=jnp.int32) - group_start[se]
        keep = rank < cap
        slot = jnp.where(keep, se * cap + rank, e * cap)
        buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(xf[st])
        return (buf[:-1].reshape(e, cap, d), live,
                (slot, st, sg, keep, expert_ids))

    buf, live, aux_info = jax.vmap(dispatch_one)(x, probs)        # (B,E,C,d)

    # Ragged grouped-GEMM counts: batch b occupies segment b of each
    # expert's folded (B*C) row range, so transposing the vmapped (B, E)
    # live counts to (E, B) with seg = cap names exactly the rows the
    # dispatch scatter filled.  Rows past counts[e, b] are zero padding the
    # fused kernel's ragged contract masks (and skips when a whole m-block
    # is dead).
    counts = jnp.transpose(live).astype(jnp.int32)                # (E, B)
    if obs_metrics.enabled():
        # kept assignments = sum of per-expert live counts; the rest hit
        # the capacity bound and ride the residual.
        dropped = b * s * k - jnp.sum(live)
        jax.debug.callback(partial(_observe_dispatch, name), live, dropped)

    # Expert GEMMs: fold batch into capacity so EP sees one (E, B*C, d) GEMM.
    xe = jnp.moveaxis(buf, 0, 1).reshape(e, b * cap, d)
    up = maybe_quantized_batched(xe, p["wi"], quant, f"{name}.wi",
                                 counts=counts, seg=cap)
    if cfg.glu:
        gate = maybe_quantized_batched(xe, p["wg"], quant, f"{name}.wg",
                                       counts=counts, seg=cap)
        h = _act(gate, cfg.act) * up
    else:
        h = _act(up, cfg.act)
    out_e = maybe_quantized_batched(h, p["wo"], quant, f"{name}.wo",
                                    counts=counts, seg=cap)
    out_e = jnp.moveaxis(out_e.reshape(e, b, cap, d), 1, 0)       # (B,E,C,d)

    def combine_one(oe, aux):
        slot, st, sg, keep, _ = aux
        flat = oe.reshape(e * cap, d)
        gathered = jnp.where(keep[:, None],
                             flat[jnp.clip(slot, 0, e * cap - 1)], 0.0)
        contrib = gathered * sg[:, None].astype(oe.dtype)
        return jnp.zeros((s, d), oe.dtype).at[st].add(contrib)

    out = jax.vmap(combine_one)(out_e, aux_info)

    # Switch-style load-balance aux loss (batch-mean).
    me = probs.mean(axis=(0, 1))                                   # (E,)
    counts = jax.nn.one_hot(aux_info[4], e, dtype=jnp.float32)     # (B,S,k,E)
    ce = counts.mean(axis=(0, 1, 2))
    aux = e * jnp.sum(me * ce)
    return out, aux
