"""Model assembly: layer-pattern scan, embeddings, heads, caches.

Decoder-only LMs (dense / MoE / hybrid / ssm / vlm) and encoder-decoder
(audio) models share the same period-scanned block machinery:

  * ``init_params``     — parameters, block params stacked over periods.
  * ``forward_train``   — full-sequence forward -> logits (+ MoE aux loss).
  * ``loss_fn``         — next-token cross-entropy.
  * ``init_cache`` / ``prefill`` / ``decode_step`` — serving path.

Every matmul site is named so the quantized KMM policy can assign per-layer
bitwidths (paper's precision-scalable use-case).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain_batch_dim
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models.config import Block, ModelConfig
from repro.quant.qmatmul import maybe_quantized_matmul

Array = jax.Array
Params = Dict[str, Any]

AUX_COEF = 0.01


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, spec: Block, cross_attn: bool,
                dtype) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": L.norm_init(cfg.d_model), "ln2": L.norm_init(cfg.d_model)}
    if spec.kind == "attn":
        p["attn"] = L.attn_init(ks[0], cfg, dtype)
    elif spec.kind == "mamba":
        p["mamba"] = S.mamba_init(ks[0], cfg, dtype)
    elif spec.kind == "rwkv":
        p["rwkv"] = R.rwkv_init(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.kind)
    if cross_attn:
        p["lnx"] = L.norm_init(cfg.d_model)
        p["xattn"] = L.attn_init(ks[1], cfg, dtype)
    if spec.moe:
        p["moe"] = M.moe_init(ks[2], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.glu, dtype)
    return p


def _stack_init(key, cfg: ModelConfig, n_periods: int, cross_attn: bool,
                dtype) -> Params:
    """Blocks stacked over periods: {posN: pytree with leading n_periods}."""
    out: Params = {}
    for pos, spec in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(key, pos), n_periods)
        out[f"pos{pos}"] = jax.vmap(
            lambda k: _block_init(k, cfg, spec, cross_attn, dtype))(keys)
    return out


def init_params(key: Array, cfg: ModelConfig) -> Params:
    dtype = _dtype(cfg)
    k_emb, k_blocks, k_enc, k_head, k_front = jax.random.split(key, 5)
    params: Params = {
        "embed": (jax.random.normal(k_emb, (cfg.padded_vocab, cfg.d_model))
                  * cfg.d_model**-0.5).astype(dtype),
        "blocks": _stack_init(k_blocks, cfg, cfg.n_periods,
                              cross_attn=cfg.is_encdec, dtype=dtype),
        "ln_f": L.norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.padded_vocab))
            * cfg.d_model**-0.5).astype(dtype)
    if cfg.is_encdec:
        params["encoder"] = _stack_init(k_enc, cfg, cfg.encoder_periods,
                                        cross_attn=False, dtype=dtype)
        params["enc_ln_f"] = L.norm_init(cfg.d_model)
    if cfg.frontend != "none":
        fd = cfg.frontend_dim
        kf1, kf2 = jax.random.split(k_front)
        params["frontend"] = {
            "w1": (jax.random.normal(kf1, (fd, cfg.d_model)) * fd**-0.5
                   ).astype(dtype),
            "w2": (jax.random.normal(kf2, (cfg.d_model, cfg.d_model))
                   * cfg.d_model**-0.5).astype(dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Blocks.
# ---------------------------------------------------------------------------


def _block_train(p: Params, x: Array, spec: Block, cfg: ModelConfig, pos: int,
                 mem: Optional[Tuple[Array, Array]] = None,
                 causal: bool = True) -> Tuple[Array, Array]:
    quant = cfg.quant
    name = f"blk{pos}.{spec.kind}"
    h = L.norm_apply(p["ln1"], x)
    if spec.kind == "attn":
        if causal:
            y = L.attn_train(p["attn"], h, cfg, quant, name)
        else:
            y = _attn_bidir(p["attn"], h, cfg, quant, name)
    elif spec.kind == "mamba":
        y = S.mamba_apply(p["mamba"], h, cfg, quant, name)
    else:
        y = R.rwkv_apply(p["rwkv"], h, cfg, quant, name)
    x = x + y
    if mem is not None:
        h = L.norm_apply(p["lnx"], x)
        x = x + L.xattn_apply(p["xattn"], h, mem[0], mem[1], cfg, quant,
                              f"blk{pos}.xattn")
    h = L.norm_apply(p["ln2"], x)
    if spec.moe:
        y, aux = M.moe_apply(p["moe"], h, cfg, quant, f"blk{pos}.moe")
    else:
        y = L.mlp_apply(p["mlp"], h, cfg.act, cfg.glu, quant, f"blk{pos}.mlp")
        aux = jnp.zeros((), jnp.float32)
    return x + y, aux


def _attn_bidir(p, x, cfg, quant, name):
    """Encoder (non-causal) attention."""
    b, s, _ = x.shape
    q, k, v = L._qkv(p, x, cfg, quant, name)
    pos = jnp.arange(s, dtype=jnp.int32)
    q = L.rope(q, pos, cfg.rope_theta)
    k = L.rope(k, pos, cfg.rope_theta)
    out = L.chunked_attention(q, k, v, causal=False)
    out = out.reshape(b, s, cfg.q_dim)
    return maybe_quantized_matmul(out, p["wo"], quant, f"{name}.wo")


def _scan_blocks(params_stack: Params, x: Array, cfg: ModelConfig,
                 mem: Optional[Params] = None,
                 causal: bool = True) -> Tuple[Array, Array]:
    """Scan the period-stacked blocks; returns (x, aux_loss_sum).

    ``mem`` (cross-attention K/V, enc-dec only) is period-stacked like the
    params and scanned alongside them.
    """

    def period(carry, xs):
        period_params, period_mem = xs
        x, aux = carry
        x = constrain_batch_dim(x)   # keep activations DP-sharded (FSDP mode)
        for pos, spec in enumerate(cfg.pattern):
            m = None if period_mem is None else period_mem[f"pos{pos}"]
            x, a = _block_train(period_params[f"pos{pos}"], x, spec, cfg, pos,
                                mem=m, causal=causal)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(period) if cfg.remat else period
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                           (params_stack, mem))
    return x, aux


# ---------------------------------------------------------------------------
# Embedding / head / frontend.
# ---------------------------------------------------------------------------


def _embed(params: Params, cfg: ModelConfig, tokens: Array) -> Array:
    x = params["embed"][tokens].astype(_cdtype(cfg))
    return x * jnp.asarray(cfg.d_model**0.5, _cdtype(cfg))


def _frontend_project(params: Params, cfg: ModelConfig, embeds: Array) -> Array:
    f = params["frontend"]
    h = maybe_quantized_matmul(embeds.astype(_cdtype(cfg)), f["w1"],
                               cfg.quant, "frontend.w1")
    h = jax.nn.gelu(h)
    return maybe_quantized_matmul(h, f["w2"], cfg.quant, "frontend.w2")


def _logits(params: Params, cfg: ModelConfig, x: Array) -> Array:
    x = L.norm_apply(params["ln_f"], x)
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    out = maybe_quantized_matmul(x, w, cfg.quant, "lm_head")
    return _mask_padded_vocab(cfg, out)


def _mask_padded_vocab(cfg: ModelConfig, logits: Array) -> Array:
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    iota = jnp.arange(cfg.padded_vocab, dtype=jnp.int32)
    return jnp.where(iota < cfg.vocab_size, logits,
                     jnp.asarray(-1e30, logits.dtype))


# ---------------------------------------------------------------------------
# Train path.
# ---------------------------------------------------------------------------


def forward_hidden(params: Params, cfg: ModelConfig, tokens: Array,
                   frontend_embeds: Optional[Array] = None,
                   enc_frames: Optional[Array] = None) -> Tuple[Array, Array]:
    """tokens: (B, S_txt). Returns (final hidden (B, S, d), aux_loss)."""
    x = constrain_batch_dim(_embed(params, cfg, tokens))
    if cfg.frontend == "vision" and frontend_embeds is not None:
        fx = _frontend_project(params, cfg, frontend_embeds)
        x = constrain_batch_dim(jnp.concatenate([fx.astype(x.dtype), x],
                                                axis=1))
    mem = None
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.is_encdec:
        assert enc_frames is not None
        ex = _frontend_project(params, cfg, enc_frames) \
            if cfg.frontend == "audio" else enc_frames.astype(_cdtype(cfg))
        ex, aux_e = _scan_blocks(params["encoder"], ex, cfg, causal=False)
        ex = L.norm_apply(params["enc_ln_f"], ex)
        aux_total = aux_total + aux_e
        # Each decoder block projects its own cross-attn K/V from ex.
        mem = _encdec_memory(params, cfg, ex)
    x, aux = _scan_blocks(params["blocks"], x, cfg, mem=mem, causal=True)
    return x, aux_total + aux


def forward_train(params: Params, cfg: ModelConfig, tokens: Array,
                  frontend_embeds: Optional[Array] = None,
                  enc_frames: Optional[Array] = None) -> Tuple[Array, Array]:
    """tokens: (B, S_txt). Returns (logits (B, S, V), aux_loss)."""
    x, aux = forward_hidden(params, cfg, tokens,
                            frontend_embeds=frontend_embeds,
                            enc_frames=enc_frames)
    return _logits(params, cfg, x), aux


def _encdec_memory(params: Params, cfg: ModelConfig, ex: Array):
    """Precompute per-(period, pos) cross-attn K/V from the encoder output.

    Returns pytree with leading n_periods dims matching the block scan; the
    scan body slices its period's K/V (the standard T5-style cache).
    """
    def per_pos(pos):
        stack = params["blocks"][f"pos{pos}"]["xattn"]
        def one(pp):
            return L.xattn_mem(pp, ex, cfg, cfg.quant, f"blk{pos}.xattn")
        return jax.vmap(one)(stack)   # (n_periods, B, T, K, D) x2
    return {f"pos{pos}": per_pos(pos) for pos in range(len(cfg.pattern))}


LOSS_CHUNK = 512


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, Array]) -> Array:
    """Next-token CE with a sequence-chunked, recomputing head.

    The (B, S, V) logits tensor is never materialized: the head matmul and
    the CE reduce run per sequence chunk under jax.checkpoint, so peak loss
    memory is (B, chunk, V/TP) and the backward recomputes each chunk's
    logits.  The gold logit is extracted with an iota==label select (not
    take_along_axis) so the vocab dim stays TP-sharded throughout.
    """
    x, aux = forward_hidden(
        params, cfg, batch["tokens"],
        frontend_embeds=batch.get("frontend_embeds"),
        enc_frames=batch.get("enc_frames"))
    labels = batch["labels"]
    if x.shape[1] != labels.shape[1]:        # vision prefix tokens: strip
        x = x[:, -labels.shape[1]:, :]
    x = L.norm_apply(params["ln_f"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    v = cfg.vocab_size

    b, s, _ = x.shape
    chunk = min(LOSS_CHUNK, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    @jax.checkpoint
    def chunk_ce(xc, lc, mc):
        logits = maybe_quantized_matmul(xc, w, cfg.quant, "lm_head")
        logits = _mask_padded_vocab(cfg, logits).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        iota = jnp.arange(cfg.padded_vocab, dtype=lc.dtype)[None, None, :]
        gold = jnp.sum(jnp.where(iota == lc[..., None], logits, 0.0), axis=-1)
        return ((logz - gold) * mc).sum()

    def body(tot, idx):
        sl = lambda t: lax.dynamic_slice_in_dim(t, idx * chunk, chunk, axis=1)
        return tot + chunk_ce(sl(x), sl(labels), sl(mask)), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(nc))
    ce = total / jnp.maximum(mask.sum(), 1.0)
    return ce + AUX_COEF * aux


# ---------------------------------------------------------------------------
# Serve path: cache init / prefill / decode.
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    dtype = _cdtype(cfg)
    cache: Params = {}
    for pos, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            c = L.attn_cache_init(cfg, batch, max_seq, dtype)
        elif spec.kind == "mamba":
            c = S.mamba_cache_init(cfg, batch, dtype)
        else:
            c = R.rwkv_cache_init(cfg, batch, dtype)
        cache[f"pos{pos}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape), c)
    return cache


def _block_decode(p: Params, x: Array, spec: Block, cache: Params, pos_idx: int,
                  t: Array, cfg: ModelConfig,
                  mem: Optional[Tuple[Array, Array]] = None,
                  positions: Optional[Array] = None,
                  kv_valid: Optional[Array] = None):
    quant = cfg.quant
    name = f"blk{pos_idx}.{spec.kind}"
    h = L.norm_apply(p["ln1"], x)
    if spec.kind == "attn":
        y, new_c = L.attn_decode(p["attn"], h, cache, t, cfg, quant, name,
                                 positions=positions, kv_valid=kv_valid)
    elif spec.kind == "mamba":
        y, new_c = S.mamba_decode(p["mamba"], h, cache, cfg, quant, name)
    else:
        y, new_c = R.rwkv_decode(p["rwkv"], h, cache, cfg, quant, name)
    x = x + y
    if mem is not None:
        h = L.norm_apply(p["lnx"], x)
        x = x + L.xattn_apply(p["xattn"], h, mem[0], mem[1], cfg, quant,
                              f"blk{pos_idx}.xattn")
    h = L.norm_apply(p["ln2"], x)
    if spec.moe:
        y, _ = M.moe_apply(p["moe"], h, cfg, quant, f"blk{pos_idx}.moe")
    else:
        y = L.mlp_apply(p["mlp"], h, cfg.act, cfg.glu, quant,
                        f"blk{pos_idx}.mlp")
    return x + y, new_c


def decode_step(params: Params, cfg: ModelConfig, token: Array, cache: Params,
                t: Array, mem: Optional[Params] = None,
                positions: Optional[Array] = None,
                kv_valid: Optional[Array] = None) -> Tuple[Array, Params]:
    """One decode step. token: (B,) int32; returns (logits (B, V), new cache).

    ``t`` is the KV-cache write index: a scalar for lock-step batches, or a
    (B,) vector for continuous batching where every slot sits at its own
    depth.  ``positions`` optionally gives distinct RoPE positions (defaults
    to ``t``); ``kv_valid`` (B, Smax) masks pad cache slots (left-padded
    prompts)."""
    x = _embed(params, cfg, token[:, None])

    def period(x, xs):
        period_params, period_cache, period_mem = xs
        new_cache = {}
        for pos, spec in enumerate(cfg.pattern):
            m = None
            if period_mem is not None:
                m = period_mem[f"pos{pos}"]
            x, nc = _block_decode(period_params[f"pos{pos}"], x, spec,
                                  period_cache[f"pos{pos}"], pos, t, cfg,
                                  mem=m, positions=positions,
                                  kv_valid=kv_valid)
            new_cache[f"pos{pos}"] = nc
        return x, new_cache

    xs = (params["blocks"], cache, mem)
    x, new_cache = lax.scan(period, x, xs)
    logits = _logits(params, cfg, x)
    return logits[:, 0, :], new_cache


PREFILL_CHUNK = 2048

# Fixed associative-scan grid for the ragged/serve prefill path.  The mamba
# recurrence is bracketing-sensitive in fp32: resume-from-offset prefill is
# bit-exact vs single-shot only when both decompose the sequence over the
# same absolute-position grid.  All serve chunk/bucket widths are multiples
# of 8, so an 8-wide grid is boundary-independent.
SSM_PREFILL_GRID = 8


def _attn_max_seq(cfg: ModelConfig, cache: Params) -> Optional[int]:
    """Smax of the attention KV cache, or None for attention-free models."""
    for pos, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            return cache[f"pos{pos}"]["k"].shape[2]
    return None


def prefill(params: Params, cfg: ModelConfig, tokens: Array, cache: Params,
            frontend_embeds: Optional[Array] = None,
            enc_frames: Optional[Array] = None,
            chunk_size: int = PREFILL_CHUNK,
            positions: Optional[Array] = None,
            pad_mask: Optional[Array] = None,
            last_idx: Optional[Array] = None,
            start: Optional[Array] = None):
    """Chunked prefill: the prompt runs through the model ``chunk_size``
    tokens at a time (vLLM/Sarathi-style), so peak activation memory is
    O(chunk * d) regardless of prompt length; attention/recurrent state
    carries across chunks through the cache.

    Ragged prompts (mixed lengths in one padded batch) are exact when the
    caller supplies:

      * ``pad_mask`` (B, S) bool — True on real tokens.  Pad keys are masked
        out of attention and the recurrent (mamba/rwkv) state is frozen
        across pad positions, so each row's final state equals a per-row
        unpadded run.
      * ``positions`` (B, S) int32 — explicit RoPE positions (left-padded
        batches, where cache index != logical position).  Defaults to
        ``arange(S)``, which is already correct for right-padded batches.
      * ``last_idx`` (B,) int32 — index of each row's last real token; the
        returned logits are taken there (and the carried recurrent state is
        snapshotted there for right-padded rows).

    Ragged calls run as a single chunk (prompts are bucketed by the serving
    engine, so S is already bounded); the plain path keeps the chunked scan.

    ``start`` (scalar int32, ragged path only) is the resume offset: the
    tokens are treated as positions ``start .. start+S-1`` of the sequence —
    KV lands at cache index ``start+j``, default RoPE positions are
    ``start+j``, and the carried recurrent state in ``cache`` is assumed to
    sit at position ``start``.  Cache contents below ``start`` are treated
    as valid earlier-chunk keys.  Chunked prefill (several resumed calls)
    is bit-exact vs one single-shot call per block type: attention always
    scores against the full Smax cache with identical masking, the rwkv
    recurrence is sequential, and the mamba scan runs on the fixed
    ``SSM_PREFILL_GRID`` so the bracketing is boundary-independent.

    Returns (last-real-position logits (B, V), cache, mem) where mem is the
    cross-attention memory for enc-dec models (None otherwise).
    """
    ragged = (positions is not None or pad_mask is not None
              or last_idx is not None or start is not None)
    if ragged and cfg.frontend == "vision" and frontend_embeds is not None:
        raise NotImplementedError(
            "ragged prefill does not support vision prefix tokens")
    x = constrain_batch_dim(_embed(params, cfg, tokens))
    if cfg.frontend == "vision" and frontend_embeds is not None:
        fx = _frontend_project(params, cfg, frontend_embeds)
        x = constrain_batch_dim(jnp.concatenate([fx.astype(x.dtype), x],
                                                axis=1))
    mem = None
    if cfg.is_encdec:
        ex = _frontend_project(params, cfg, enc_frames) \
            if cfg.frontend == "audio" else enc_frames.astype(_cdtype(cfg))
        ex, _ = _scan_blocks(params["encoder"], ex, cfg, causal=False)
        ex = L.norm_apply(params["enc_ln_f"], ex)
        mem = _encdec_memory(params, cfg, ex)

    b, s, _ = x.shape
    off = jnp.int32(0) if start is None else jnp.asarray(start, jnp.int32)
    kv_valid = None
    if pad_mask is not None:
        smax = _attn_max_seq(cfg, cache)
        if smax is not None:
            # Absolute-position validity over the whole cache: positions
            # below the resume offset hold real earlier-chunk keys, the
            # current chunk maps through pad_mask, and future positions stay
            # True (the causal mask already hides them).
            kvpos = jnp.arange(smax, dtype=jnp.int32)[None, :]
            rel = jnp.clip(kvpos - off, 0, s - 1)
            in_chunk = (kvpos >= off) & (kvpos < off + s)
            chunk_valid = jnp.take_along_axis(
                pad_mask, jnp.broadcast_to(rel, (b, smax)), axis=1)
            kv_valid = jnp.where(in_chunk, chunk_valid, True)

    # Ragged/serve calls run the mamba scan on the fixed grid so chunked
    # prefill brackets identically to single-shot; the plain (train-shaped)
    # path keeps the default wide chunk.
    ssm_chunk = SSM_PREFILL_GRID if ragged else S.MAMBA_SCAN_CHUNK

    def run_chunk(chunk_cache, xc, offset, pos_c, mask_c, li):
        """One chunk through all periods; pos_c/mask_c/li are the ragged
        extras (None on the plain path)."""

        def period(carry, xs):
            xc, offset = carry
            period_params, period_cache, period_mem = xs
            new_cache = {}
            for pos, spec in enumerate(cfg.pattern):
                p = period_params[f"pos{pos}"]
                quant = cfg.quant
                name = f"blk{pos}.{spec.kind}"
                h = L.norm_apply(p["ln1"], xc)
                if spec.kind == "attn":
                    y, nc = L.attn_prefill_chunk(
                        p["attn"], h, period_cache[f"pos{pos}"], offset, cfg,
                        quant, name, positions=pos_c, kv_valid=kv_valid)
                elif spec.kind == "mamba":
                    y, nc = S.mamba_apply_stateful(
                        p["mamba"], h, period_cache[f"pos{pos}"], cfg, quant,
                        name, chunk=ssm_chunk, mask=mask_c, last_idx=li)
                else:
                    y, nc = R.rwkv_apply_stateful(
                        p["rwkv"], h, period_cache[f"pos{pos}"], cfg, quant,
                        name, mask=mask_c, last_idx=li)
                xc = xc + y
                if period_mem is not None:
                    hm = L.norm_apply(p["lnx"], xc)
                    pm = period_mem[f"pos{pos}"]
                    xc = xc + L.xattn_apply(p["xattn"], hm, pm[0], pm[1], cfg,
                                            quant, f"blk{pos}.xattn")
                h = L.norm_apply(p["ln2"], xc)
                if spec.moe:
                    y, _ = M.moe_apply(p["moe"], h, cfg, quant,
                                       f"blk{pos}.moe")
                else:
                    y = L.mlp_apply(p["mlp"], h, cfg.act, cfg.glu, quant,
                                    f"blk{pos}.mlp")
                xc = xc + y
                new_cache[f"pos{pos}"] = nc
            return (xc, offset), new_cache

        (xc, _), new_cache = lax.scan(period, (xc, offset),
                                      (params["blocks"], chunk_cache, mem))
        return new_cache, xc

    if ragged:
        li = (last_idx.astype(jnp.int32) if last_idx is not None
              else jnp.full((b,), s - 1, jnp.int32))
        cache, xall = run_chunk(cache, x, off, positions, pad_mask, li)
        last_h = jnp.take_along_axis(xall, li[:, None, None], axis=1)
        logits = _logits(params, cfg, last_h)
        return logits[:, 0, :], cache, mem

    cs = min(chunk_size, s)
    while s % cs:
        cs //= 2
    n_chunks = s // cs

    def chunk_step(chunk_cache, ci):
        offset = ci * cs
        xc = lax.dynamic_slice_in_dim(x, offset, cs, axis=1)
        new_cache, xc = run_chunk(chunk_cache, xc, offset, None, None, None)
        return new_cache, xc[:, -1]

    cache, lasts = lax.scan(chunk_step, cache,
                            jnp.arange(n_chunks, dtype=jnp.int32))
    logits = _logits(params, cfg, lasts[-1][:, None, :])
    return logits[:, 0, :], cache, mem
