"""Model building blocks: norms, RoPE, attention (train + KV-cache decode),
dense MLPs.  Every weight matmul routes through the quantized KMM path when
the config enables it (`maybe_quantized_matmul`), making the paper's integer
GEMM engine a first-class execution mode for all architectures.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.qmatmul import maybe_quantized_matmul

Array = jax.Array
Params = Dict[str, Array]


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


def norm_init(d: int, kind: str = "rms") -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "ln":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p: Params, x: Array, kind: str = "rms", eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "ln":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, D) with D even; positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]   # (S, D/2)
        ang = ang[None, :, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs          # (B,S,D/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLP.
# ---------------------------------------------------------------------------


def _act(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def mlp_init(key, d: int, ff: int, glu: bool, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d**-0.5, ff**-0.5
    p = {"wo": (jax.random.normal(k3, (ff, d)) * s_out).astype(dtype)}
    p["wi"] = (jax.random.normal(k1, (d, ff)) * s_in).astype(dtype)
    if glu:
        p["wg"] = (jax.random.normal(k2, (d, ff)) * s_in).astype(dtype)
    return p


def mlp_apply(p: Params, x: Array, act: str, glu: bool, quant, name: str) -> Array:
    up = maybe_quantized_matmul(x, p["wi"], quant, f"{name}.wi")
    if glu:
        gate = maybe_quantized_matmul(x, p["wg"], quant, f"{name}.wg")
        h = _act(gate, act) * up
    else:
        h = _act(up, act)
    return maybe_quantized_matmul(h, p["wo"], quant, f"{name}.wo")


# ---------------------------------------------------------------------------
# Attention (GQA/MQA) — chunked-causal for train/prefill, KV cache for decode.
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype) -> Params:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d**-0.5
    return {
        "wq": (jax.random.normal(kq, (d, qd)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, kvd)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, kvd)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (qd, d)) * qd**-0.5).astype(dtype),
    }


def _qkv(p: Params, x: Array, cfg, quant, name: str):
    b, s, _ = x.shape
    q = maybe_quantized_matmul(x, p["wq"], quant, f"{name}.wq")
    k = maybe_quantized_matmul(x, p["wk"], quant, f"{name}.wk")
    v = maybe_quantized_matmul(x, p["wv"], quant, f"{name}.wv")
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                      chunk: int = 256) -> Array:
    """Memory-bounded attention (flash-style query chunking).

    q: (B, S, H, D); k, v: (B, T, K, D) with H = K * G.  Scores for one query
    chunk against all keys are materialized at a time: O(chunk * T) memory.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = d**-0.5
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    qr = q.reshape(b, nc, chunk, kh, g, d)
    kt = k.astype(q.dtype)
    vt = v.astype(q.dtype)
    positions = jnp.arange(k.shape[1], dtype=jnp.int32)

    @jax.checkpoint   # recompute scores/probs in bwd: O(c*T) not O(S*T) live
    def one_chunk(ci):
        qc = qr[:, ci]                                       # (B, c, K, G, D)
        scores = jnp.einsum("bckgd,bskd->bckgs", qc, kt).astype(jnp.float32)
        scores = scores * scale
        if causal:
            row = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
            mask = positions[None, :] <= row[:, None]        # (c, T)
            scores = jnp.where(mask[None, :, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bckgs,bskd->bckgd", probs, vt)

    out = jax.lax.map(one_chunk, jnp.arange(nc))             # (nc, B, c, K, G, D)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)
    return out


# Backwards-compatible alias.
def chunked_causal_attention(q, k, v, *, chunk: int = 256):
    return chunked_attention(q, k, v, causal=True, chunk=chunk)


def attn_train(p: Params, x: Array, cfg, quant, name: str,
               positions: Optional[Array] = None,
               chunk: int = 256) -> Array:
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, quant, name)
    pos = positions if positions is not None else jnp.arange(s, dtype=jnp.int32)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    out = chunked_causal_attention(q, k, v, chunk=chunk)
    out = out.reshape(b, s, cfg.q_dim)
    return maybe_quantized_matmul(out, p["wo"], quant, f"{name}.wo")


def attn_cache_init(cfg, batch: int, max_seq: int, dtype) -> Params:
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cached_attention(q: Array, ck: Array, cv: Array, q_offset: Array, *,
                     kv_valid: Optional[Array] = None,
                     chunk: int = 256) -> Array:
    """Attention of a query chunk against the (partially filled) KV cache.

    q: (B, c, H, D) at global positions q_offset..q_offset+c-1;
    ck/cv: (B, Smax, K, D).  Row r attends kv positions <= q_offset + r.
    ``kv_valid`` (B, Smax) additionally masks out cache slots that hold pad
    tokens (ragged left-padded prompts).
    Peak memory O(sub_chunk * Smax) — the chunked-prefill working set.
    """
    b, c, h, d = q.shape
    kh = ck.shape[2]
    g = h // kh
    scale = d**-0.5
    sub = min(chunk, c)
    while c % sub:
        sub //= 2
    nc = c // sub
    qr = q.reshape(b, nc, sub, kh, g, d)
    kt = ck.astype(q.dtype)
    vt = cv.astype(q.dtype)
    kvpos = jnp.arange(ck.shape[1], dtype=jnp.int32)

    @jax.checkpoint
    def one_chunk(ci):
        qc = qr[:, ci]                                       # (B, sub, K, G, D)
        scores = jnp.einsum("bckgd,bskd->bckgs", qc, kt).astype(jnp.float32)
        scores = scores * scale
        row = q_offset + ci * sub + jnp.arange(sub, dtype=jnp.int32)
        mask = (kvpos[None, :] <= row[:, None])[None]        # (1, sub, Smax)
        if kv_valid is not None:
            mask = jnp.logical_and(mask, kv_valid[:, None, :])
        scores = jnp.where(mask[:, :, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bckgs,bskd->bckgd", probs, vt)

    out = jax.lax.map(one_chunk, jnp.arange(nc))
    out = jnp.moveaxis(out, 0, 1).reshape(b, c, h, d)
    return out


def attn_prefill_chunk(p: Params, x: Array, cache: Params, offset: Array,
                       cfg, quant, name: str,
                       positions: Optional[Array] = None,
                       kv_valid: Optional[Array] = None
                       ) -> Tuple[Array, Params]:
    """One chunked-prefill step: project the chunk, extend the KV cache at
    ``offset``, attend against everything cached so far.

    ``positions`` (B, c) overrides the RoPE positions (ragged prompts where
    cache index != logical position); ``kv_valid`` (B, Smax) masks pad slots.
    """
    b, c, _ = x.shape
    q, k, v = _qkv(p, x, cfg, quant, name)
    pos = positions if positions is not None \
        else offset + jnp.arange(c, dtype=jnp.int32)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, offset, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, offset, 0, 0))
    out = cached_attention(q, ck, cv, offset, kv_valid=kv_valid)
    out = out.reshape(b, c, cfg.q_dim)
    out = maybe_quantized_matmul(out, p["wo"], quant, f"{name}.wo")
    return out, {"k": ck, "v": cv}


def _as_batch_vec(pos, b: int) -> Array:
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(pos, (b,)) if pos.ndim == 0 else pos


def attn_decode(p: Params, x: Array, cache: Params, pos: Array, cfg, quant,
                name: str, positions: Optional[Array] = None,
                kv_valid: Optional[Array] = None) -> Tuple[Array, Params]:
    """One-token decode: x (B, 1, d); cache k/v (B, Smax, K, D).

    ``pos`` is the cache write index — a scalar (lock-step batch) or a (B,)
    vector (continuous batching: each slot at its own depth).  ``positions``
    optionally supplies distinct RoPE positions (left-padded caches where
    cache index != logical position); ``kv_valid`` (B, Smax) masks pad slots.
    """
    b = x.shape[0]
    q, k, v = _qkv(p, x, cfg, quant, name)
    pos_b = _as_batch_vec(pos, b)
    rpos = pos_b if positions is None else _as_batch_vec(positions, b)
    q = rope(q, rpos[:, None], cfg.rope_theta)
    k = rope(k, rpos[:, None], cfg.rope_theta)

    def write(c, u, start):
        return jax.lax.dynamic_update_slice(c, u.astype(c.dtype),
                                            (start, 0, 0))

    ck = jax.vmap(write)(cache["k"], k, pos_b)
    cv = jax.vmap(write)(cache["v"], v, pos_b)
    kh, d = cfg.n_kv_heads, cfg.head_dim
    g = cfg.n_heads // kh
    qv = q.reshape(b, kh, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qv,
                        ck.astype(q.dtype)).astype(jnp.float32)
    scores = scores * (d**-0.5)
    valid = (jnp.arange(ck.shape[1], dtype=jnp.int32)[None, :]
             <= pos_b[:, None])                              # (B, Smax)
    if kv_valid is not None:
        valid = jnp.logical_and(valid, kv_valid)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cv.astype(q.dtype))
    out = out.reshape(b, 1, cfg.q_dim)
    out = maybe_quantized_matmul(out, p["wo"], quant, f"{name}.wo")
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder).
# ---------------------------------------------------------------------------


def xattn_apply(p: Params, x: Array, mem_k: Array, mem_v: Array, cfg, quant,
                name: str) -> Array:
    """x: (B, S, d) queries; mem_k/mem_v: (B, T, K, D) precomputed from the
    encoder output (cached once per request)."""
    b, s, _ = x.shape
    q = maybe_quantized_matmul(x, p["wq"], quant, f"{name}.wq")
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    kh, d = cfg.n_kv_heads, cfg.head_dim
    g = cfg.n_heads // kh
    qv = q.reshape(b, s, kh, g, d)
    scores = jnp.einsum("bskgd,btkd->bskgt", qv,
                        mem_k.astype(q.dtype)).astype(jnp.float32) * (d**-0.5)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bskgt,btkd->bskgd", probs, mem_v.astype(q.dtype))
    out = out.reshape(b, s, cfg.q_dim)
    return maybe_quantized_matmul(out, p["wo"], quant, f"{name}.wo")


def xattn_mem(p: Params, enc_out: Array, cfg, quant, name: str):
    """Project encoder output to cross-attention K/V once."""
    b, t, _ = enc_out.shape
    k = maybe_quantized_matmul(enc_out, p["wk"], quant, f"{name}.wk")
    v = maybe_quantized_matmul(enc_out, p["wv"], quant, f"{name}.wv")
    return (k.reshape(b, t, cfg.n_kv_heads, cfg.head_dim),
            v.reshape(b, t, cfg.n_kv_heads, cfg.head_dim))
