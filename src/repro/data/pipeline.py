"""Deterministic synthetic data pipeline with restart-safe skip-ahead.

Batches are pure functions of (seed, step), so any host can regenerate any
step's global batch without coordination: restarts, elastic re-sharding, and
straggler-evicted replacements all resume bit-identically by construction.
A real deployment swaps `_synthesize` for tokenized shards; the step-indexed
contract (and the tests that pin it) stay the same.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    frontend: str = "none"        # mirror of model config
    frontend_dim: int = 0
    frontend_tokens: int = 0
    encdec: bool = False


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def _synthesize(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Structured synthetic LM data: repeated n-gram motifs, not iid noise,
    so the training loss has signal to minimize."""
    rng = _rng_for(cfg.seed, step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    motif_len = 16
    n_motifs = 64
    motifs = _rng_for(cfg.seed, 0x5EED0).integers(
        0, v, size=(n_motifs, motif_len))
    picks = rng.integers(0, n_motifs, size=(b, s // motif_len + 1))
    tokens = motifs[picks].reshape(b, -1)[:, :s].astype(np.int32)
    noise = rng.random((b, s)) < 0.05
    tokens = np.where(noise, rng.integers(0, v, size=(b, s)), tokens)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    mask = np.ones((b, s), np.float32)
    mask[:, -1] = 0.0
    out = {"tokens": tokens, "labels": labels, "mask": mask}
    if cfg.frontend == "vision":
        out["frontend_embeds"] = rng.standard_normal(
            (b, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
    if cfg.encdec:
        out["enc_frames"] = rng.standard_normal(
            (b, s, cfg.frontend_dim or 160)).astype(np.float32)
    return out


class DataIterator:
    """Step-indexed iterator; ``skip_to(step)`` is O(1) (restart-safe)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def skip_to(self, step: int) -> None:
        self.step = step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = _synthesize(self.cfg, self.step)
        self.step += 1
        return batch

    def peek(self, step: Optional[int] = None) -> Dict[str, np.ndarray]:
        return _synthesize(self.cfg, self.step if step is None else step)
