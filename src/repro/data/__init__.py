from repro.data.pipeline import DataConfig, DataIterator
