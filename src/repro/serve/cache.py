"""Paged KV / recurrent-state pool + prefix sharing for the serve engine.

The engine's decode cache is not a dense ``(n_periods, B, ...)`` pytree any
more: every leaf lives in a *pool* with a row dimension at axis 1, and the
mapping from decode slots to pool rows is data, not layout:

  * attention K/V leaves are split into fixed-size **pages** of
    ``page_size`` tokens: pool layout ``(n_periods, n_pages, page, K, D)``,
    slot -> pages through a ``(n_slots, pages_per_slot)`` int32 page table.
  * recurrent leaves (mamba conv/ssm, rwkv shift/wkv) are a single state
    row per slot: pool layout ``(n_periods, n_state_rows, ...)``, slot ->
    row through a ``(n_slots,)`` int32 state table.

The tables are jit-visible arrays: the executor's gather/scatter jits take
them as device operands, so repointing a slot at different pages never
retraces.  Beyond the per-slot rows the pool keeps

  * a **snapshot region** (``snapshot_slots`` extra slots' worth of pages
    and state rows) backing :class:`PrefixCache` prompt-prefix snapshots,
    allocated/freed through explicit free lists, and
  * one **parking** row set: decode lanes padding a bucketed batch beyond
    the free-slot supply gather from (and scatter garbage into) the parking
    rows, so padded lanes can never corrupt a live slot or a snapshot.

Prefix sharing is copy-on-reference: a snapshot stores a *copy* of the
slot's first ``L / page_size`` pages plus its recurrent state row captured
exactly at position ``L`` (a chunk boundary, so the state is bit-exact),
and a hit copies the snapshot back into the new slot's rows before prefill
resumes at offset ``L``.  Hit == cold holds bitwise because chunked prefill
itself is bit-exact (models/lm.py ``start=`` contract).

Under a mesh the pools are placed with
:func:`repro.dist.sharding.page_pool_sharding` (pages/state rows over the
data axes, kv-heads / inner dims over ``model``) and the copy/zero jits pin
their outputs to the same sharding, so pool state never ping-pongs layouts.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as dist_sharding
from repro.models import lm
from repro.obs import metrics as obs_metrics

Params = Any

# Prefix-cache traffic (host-side; PrefixCache also keeps its own
# hits/misses ints for stats() — the counter is the scrapeable form).
_PREFIX_EVENTS = obs_metrics.counter(
    "repro_serve_prefix_cache_total",
    "prompt-prefix cache events (hit/miss/store/evict)",
    labels=("event",))

# Cache leaves that carry a per-token Smax axis and therefore page.
PAGED_LEAVES = ("k", "v")


def is_paged_leaf(path) -> bool:
    """True when a cache/pool pytree path names a paged (attn K/V) leaf."""
    return dist_sharding._path_names(path)[-1] in PAGED_LEAVES


def default_page_size(max_seq: int, preferred: int = 64) -> int:
    """Largest power of two <= ``preferred`` dividing ``max_seq``."""
    p = preferred
    while p > 1 and max_seq % p:
        p //= 2
    return p


class PagedCachePool:
    """Fixed-size page / state-row pools plus slot tables and free lists."""

    def __init__(self, cfg, n_slots: int, max_seq: int, page_size: int, *,
                 snapshot_slots: int = 0, mesh=None):
        if max_seq % page_size:
            raise ValueError(f"page_size={page_size} must divide "
                             f"max_seq={max_seq}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_slot = pps = max_seq // page_size
        self.mesh = mesh

        # +1 slot's worth of parking rows (padded decode lanes land there).
        n_pages = (n_slots + snapshot_slots + 1) * pps
        n_states = n_slots + snapshot_slots + 1
        if mesh is not None:
            d = 1
            for a in dist_sharding.data_axes(mesh):
                d *= dist_sharding.mesh_axis_size(mesh, a)
            n_pages = -(-n_pages // d) * d     # divisible: pages shard evenly
            n_states = -(-n_states // d) * d
        self.n_pages = n_pages
        self.n_states = n_states

        shapes = jax.eval_shape(lambda: lm.init_cache(cfg, 1, max_seq))

        def pool_leaf(path, leaf):
            if is_paged_leaf(path):
                # (n_periods, 1, Smax, K, D) -> (n_periods, P, page, K, D)
                assert leaf.shape[2] == max_seq, leaf.shape
                shape = (leaf.shape[0], n_pages, page_size) + leaf.shape[3:]
            else:
                shape = (leaf.shape[0], n_states) + leaf.shape[2:]
            return jnp.zeros(shape, leaf.dtype)

        self.pools = jax.tree_util.tree_map_with_path(pool_leaf, shapes)
        self.sharding = None
        if mesh is not None:
            self.sharding = dist_sharding.page_pool_sharding(
                jax.eval_shape(lambda: self.pools), mesh)
            self.pools = jax.tree.map(jax.device_put, self.pools,
                                      self.sharding)

        # Slot rows are fixed for the engine's lifetime; the snapshot region
        # cycles through the free lists.
        self.page_table = np.empty((n_slots, pps), np.int32)
        free_pages = list(range(n_pages))
        for i in range(n_slots):
            self.page_table[i] = [free_pages.pop(0) for _ in range(pps)]
        self.parking_pages = np.array(
            [free_pages.pop(0) for _ in range(pps)], np.int32)
        self.state_table = np.arange(n_slots, dtype=np.int32)
        free_states = list(range(n_slots, n_states))
        self.parking_state = free_states.pop(0)
        self._free_pages: List[int] = free_pages
        self._free_states: List[int] = free_states

        out_sh = self.sharding
        self._zero = jax.jit(self._zero_impl, donate_argnums=(0,),
                             out_shardings=out_sh)
        self._copy = jax.jit(self._copy_impl, donate_argnums=(0,),
                             out_shardings=out_sh)

    # -- jitted pool ops ----------------------------------------------------

    @staticmethod
    def _zero_impl(pools, state_row):
        """Zero one state row across all recurrent pools (slot (re)init)."""
        def leaf(path, pool):
            if is_paged_leaf(path):
                return pool    # stale K/V is masked, never zeroed
            return pool.at[:, state_row].set(
                jnp.zeros(pool.shape[2:], pool.dtype))
        return jax.tree_util.tree_map_with_path(leaf, pools)

    @staticmethod
    def _copy_impl(pools, src_pages, dst_pages, src_state, dst_state):
        """Copy page rows + one state row (snapshot take / restore)."""
        def leaf(path, pool):
            if is_paged_leaf(path):
                return pool.at[:, dst_pages].set(pool[:, src_pages])
            return pool.at[:, dst_state].set(pool[:, src_state])
        return jax.tree_util.tree_map_with_path(leaf, pools)

    # -- host-side API ------------------------------------------------------

    def zero_slot_state(self, slot: int):
        self.pools = self._zero(self.pools,
                                jnp.int32(self.state_table[slot]))

    def _copy_rows(self, src_pages, dst_pages, src_state, dst_state):
        self.pools = self._copy(
            self.pools, jnp.asarray(src_pages, jnp.int32),
            jnp.asarray(dst_pages, jnp.int32), jnp.int32(src_state),
            jnp.int32(dst_state))

    def take_snapshot(self, slot: int, n_pages: int
                      ) -> Optional[Tuple[Tuple[int, ...], int]]:
        """Copy the slot's first ``n_pages`` pages + state row into freshly
        allocated snapshot rows; returns ``(page_rows, state_row)`` or None
        when the snapshot region is exhausted (caller evicts and retries)."""
        if len(self._free_pages) < n_pages or not self._free_states:
            return None
        rows = tuple(self._free_pages.pop(0) for _ in range(n_pages))
        srow = self._free_states.pop(0)
        self._copy_rows(self.page_table[slot, :n_pages], rows,
                        self.state_table[slot], srow)
        return rows, srow

    def restore_snapshot(self, slot: int, handle: Tuple[Tuple[int, ...], int]):
        """Copy-on-reference: snapshot rows -> the slot's own rows."""
        rows, srow = handle
        self._copy_rows(rows, self.page_table[slot, :len(rows)], srow,
                        self.state_table[slot])

    def release_snapshot(self, handle: Tuple[Tuple[int, ...], int]):
        rows, srow = handle
        self._free_pages.extend(rows)
        self._free_states.append(srow)

    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def n_free_states(self) -> int:
        return len(self._free_states)

    def lane_rows(self, lane_slots: Sequence[Optional[int]]
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """(page_rows (W, pps), state_rows (W,)) for a decode/prefill lane
        list; ``None`` entries map to the parking rows."""
        prows = np.stack([self.page_table[i] if i is not None
                          else self.parking_pages for i in lane_slots])
        srows = np.array([self.state_table[i] if i is not None
                          else self.parking_state for i in lane_slots],
                         np.int32)
        return prows, srows


class PrefixCache:
    """LRU prompt-prefix snapshots over a :class:`PagedCachePool`.

    Keys are ``tuple(prompt[:L])`` with ``L`` a multiple of ``align``
    (lcm of page size and prefill chunk, so snapshots sit on both a page
    and a chunk boundary and the recurrent state is captured bit-exactly).
    """

    def __init__(self, pool: PagedCachePool, align: int,
                 max_entries: int = 16):
        self.pool = pool
        self.align = align
        self.max_entries = max_entries
        self._entries: "OrderedDict[Tuple[int, ...], Tuple" \
            "[Tuple[Tuple[int, ...], int], int]]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def boundary_for(self, prompt_len: int) -> int:
        """Longest snapshot boundary usable for this prompt (0: none).
        At least one token must remain to prefill (the first sampled token
        comes from the prefill logits), hence ``<= prompt_len - 1``."""
        return ((prompt_len - 1) // self.align) * self.align \
            if prompt_len > self.align else 0

    def lookup(self, prompt: Sequence[int]) -> Tuple[int, bool]:
        """Longest cached prefix of ``prompt``; restores nothing itself.
        Returns ``(L, hit)`` with ``L == 0`` on a miss."""
        L = self.boundary_for(len(prompt))
        while L > 0:
            key = tuple(prompt[:L])
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                _PREFIX_EVENTS.inc("hit")
                return L, True
            L -= self.align
        self.misses += 1
        _PREFIX_EVENTS.inc("miss")
        return 0, False

    def restore(self, slot: int, prompt: Sequence[int], L: int):
        handle, _ = self._entries[tuple(prompt[:L])]
        self.pool.restore_snapshot(slot, handle)

    def store(self, slot: int, prompt: Sequence[int], L: int):
        """Snapshot the slot's first ``L`` positions (L page- and
        chunk-aligned; the slot's prefill must sit exactly at offset L)."""
        key = tuple(prompt[:L])
        if L == 0 or key in self._entries:
            return
        n_pages = L // self.pool.page_size
        handle = self.pool.take_snapshot(slot, n_pages)
        while handle is None and self._entries:
            _, (old, _) = self._entries.popitem(last=False)   # LRU evict
            self.pool.release_snapshot(old)
            _PREFIX_EVENTS.inc("evict")
            handle = self.pool.take_snapshot(slot, n_pages)
        if handle is None:
            return
        self._entries[key] = (handle, L)
        _PREFIX_EVENTS.inc("store")
        while len(self._entries) > self.max_entries:
            _, (old, _) = self._entries.popitem(last=False)
            self.pool.release_snapshot(old)
            _PREFIX_EVENTS.inc("evict")

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses}
