from repro.serve.engine import Engine, Request, ServeStats
