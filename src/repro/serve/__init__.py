from repro.serve.cache import PagedCachePool, PrefixCache
from repro.serve.engine import Engine, Request, ServeStats
from repro.serve.scheduler import Scheduler, decode_widths_for, \
    prompt_buckets_for

__all__ = ["Engine", "Request", "ServeStats", "Scheduler", "PagedCachePool",
           "PrefixCache", "decode_widths_for", "prompt_buckets_for"]
