"""Jit layer of the serve engine: paged-pool gather/compute/scatter.

The executor owns the compiled entry points the engine steps through:

  * ``decode``  — gather the lane slots' pages/state rows into a dense
    ``(n_periods, W, ...)`` cache, run :func:`repro.models.lm.decode_step`,
    scatter the lanes back.  One trace per decode-bucket width ``W``
    (shape-keyed jit cache); the pool pytree is donated every call so the
    cache state never copies.
  * ``prefill`` — same gather/scatter around a resume-from-offset
    :func:`repro.models.lm.prefill` call (``start=`` is a traced scalar, so
    one trace covers every chunk offset of a given chunk width).
  * ``sample``  — per-(request, step) keyed sampling, vmapped over lanes.

Under a mesh the pool outputs are pinned to
:func:`repro.dist.sharding.page_pool_sharding` so GSPMD never ping-pongs
the pool layout between calls, and every call runs inside the mesh context
(the engine supplies it) so quantized GEMMs negotiate shard-mapping as in
the dense-cache engine.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.obs import metrics as obs_metrics
from repro.serve.cache import PagedCachePool, is_paged_leaf

Params = Any

# Compile events per jit kind (decode/prefill/sample): tracked as deltas of
# the jit cache size after each call, only when metrics are enabled —
# steady-state decode must show zero growth (the retrace regression the
# serve bench gates on).
_RETRACES = obs_metrics.counter(
    "repro_serve_retraces_total",
    "jit (re)compiles observed by the serve executor, by kind",
    labels=("kind",))
_LANE_WIDTHS = obs_metrics.counter(
    "repro_serve_decode_lane_width_total",
    "decode calls by bucketed lane width",
    labels=("width",))


class Executor:
    """Compiled gather/compute/scatter over a :class:`PagedCachePool`."""

    def __init__(self, cfg, params: Params, pool: PagedCachePool,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.pool = pool
        self.mesh = mesh
        pps, page, smax = pool.pages_per_slot, pool.page_size, pool.max_seq

        def gather(pools, prows, srows):
            def leaf(path, pool_arr):
                if is_paged_leaf(path):
                    lanes = pool_arr[:, prows]   # (np, W, pps, page, K, D)
                    w = prows.shape[0]
                    return lanes.reshape(
                        (pool_arr.shape[0], w, pps * page)
                        + pool_arr.shape[3:])
                return pool_arr[:, srows]
            return jax.tree_util.tree_map_with_path(leaf, pools)

        def scatter(pools, lanes, prows, srows):
            def leaf(path, pool_arr, lane):
                if is_paged_leaf(path):
                    w = lane.shape[1]
                    lane = lane.reshape(
                        (pool_arr.shape[0], w, pps, page)
                        + pool_arr.shape[3:])
                    return pool_arr.at[:, prows].set(
                        lane.astype(pool_arr.dtype))
                return pool_arr.at[:, srows].set(lane.astype(pool_arr.dtype))
            return jax.tree_util.tree_map_with_path(leaf, pools, lanes)

        def decode_impl(p, pools, prows, srows, toks, pos):
            lanes = gather(pools, prows, srows)
            logits, lanes = lm.decode_step(p, cfg, toks, lanes, pos)
            return logits, scatter(pools, lanes, prows, srows)

        def prefill_impl(p, pools, prows, srows, toks, start, last):
            lanes = gather(pools, prows, srows)
            iota = jnp.arange(toks.shape[1], dtype=jnp.int32)[None, :]
            mask = iota <= last[:, None]
            logits, lanes, _ = lm.prefill(p, cfg, toks, lanes,
                                          pad_mask=mask, last_idx=last,
                                          start=start)
            return logits, scatter(pools, lanes, prows, srows)

        out_sh = None
        if mesh is not None:
            # Pin only the pool outputs: they are the carried state whose
            # layout must not ping-pong call to call.  Logits are fresh
            # per-call outputs — GSPMD picks their layout.
            out_sh = (None, pool.sharding)
        self._decode = jax.jit(decode_impl, donate_argnums=(1,),
                               out_shardings=out_sh)
        self._prefill = jax.jit(prefill_impl, donate_argnums=(1,),
                                out_shardings=out_sh)
        self._sample = jax.jit(self._sample_fn)
        self._seen_traces: Dict[str, int] = {}

    def _note_traces(self, kind: str, fn) -> None:
        """Count jit-cache growth since the last call of ``kind`` (metrics
        enabled only; no-op when the jax version hides cache sizes)."""
        if not obs_metrics.enabled():
            return
        get = getattr(fn, "_cache_size", None)
        if not callable(get):
            return
        n = int(get())
        prev = self._seen_traces.get(kind, 0)
        if n > prev:
            _RETRACES.inc(kind, by=n - prev)
        self._seen_traces[kind] = n

    # -- entry points (mutate pool.pools in place) --------------------------

    def decode(self, lane_slots, toks: np.ndarray, pos: np.ndarray):
        _LANE_WIDTHS.inc(len(lane_slots))
        prows, srows = self.pool.lane_rows(lane_slots)
        logits, self.pool.pools = self._decode(
            self.params, self.pool.pools, jnp.asarray(prows),
            jnp.asarray(srows), jnp.asarray(toks), jnp.asarray(pos))
        self._note_traces("decode", self._decode)
        return logits

    def prefill(self, slot: int, toks: np.ndarray, start: int,
                last: np.ndarray):
        prows, srows = self.pool.lane_rows([slot])
        logits, self.pool.pools = self._prefill(
            self.params, self.pool.pools, jnp.asarray(prows),
            jnp.asarray(srows), jnp.asarray(toks), jnp.int32(start),
            jnp.asarray(last))
        self._note_traces("prefill", self._prefill)
        return logits

    @staticmethod
    def _sample_fn(key, logits, temps, rids, steps):
        def one(lg, tmp, rid, st):
            k = jax.random.fold_in(jax.random.fold_in(key, rid), st)
            scaled = lg.astype(jnp.float32) / jnp.maximum(tmp, 1e-6)
            sampled = jax.random.categorical(k, scaled)
            return jnp.where(tmp > 0, sampled.astype(jnp.int32),
                             jnp.argmax(lg).astype(jnp.int32))

        return jax.vmap(one)(logits, temps, rids, steps)

    def sample(self, key, logits, temps, rids, steps):
        return self._sample(key, logits, jnp.asarray(temps),
                            jnp.asarray(rids), jnp.asarray(steps))

    def n_traces(self) -> Dict[str, int]:
        """Compiled-trace counts (retrace monitoring for the serve bench);
        -1 per entry if the jax version doesn't expose cache sizes.
        ``decode`` counts one trace per decode-bucket width, ``prefill``
        one per chunk/bucket width."""

        def size(fn) -> int:
            get = getattr(fn, "_cache_size", None)
            return int(get()) if callable(get) else -1

        return {
            "decode": size(self._decode),
            "prefill": size(self._prefill),
            "sample": size(self._sample),
        }
