"""Continuous-batching serve engine: slot scheduler + prefill/decode jits.

The engine owns ``batch_size`` decode *slots* backed by one fixed-shape KV /
recurrent cache.  Requests are admitted into freed slots as soon as they
open — there is no group barrier, so a 1-token request next to a 64-token
request costs one step, not sixty-four.  All matmuls ride the model's
quantized KMM policy — this is the paper's deployment scenario (integer
inference accelerator).

Correctness on ragged prompts
  Prompts are right-padded to a small set of fixed bucket lengths and
  prefilled one request at a time with ``pad_mask``/``last_idx`` threaded
  into :func:`repro.models.lm.prefill`, so RoPE positions, attention masks
  and recurrent (mamba/rwkv) states are exact per request.  The prefilled
  batch-1 cache is inserted into the request's slot; decode then runs the
  whole slot batch with a per-slot position vector
  (:func:`repro.models.lm.decode_step` with ``t: (B,)``).  Pad keys written
  past a prompt's end are never attended: the causal mask excludes indices
  above the slot's position and decode overwrites each index before it
  becomes visible.

Fixed shapes / no per-group retracing
  One decode trace per engine (shapes ``(B,)``), one prefill trace per
  prompt bucket (power-of-two lengths), one insert trace, two sampler
  traces.  Admission order and per-(request, step) sampling keys make
  output token-identical to sequential single-request generation, for
  greedy and temperature sampling alike.

Pass ``mesh=`` to serve sharded: params take the ``repro.dist.sharding``
param rules, the slot cache takes the cache rules (slots over ``data``,
kv-heads over ``model``), and prefill/decode jits run under the mesh so
GSPMD partitions them (DESIGN.md §4.3).  With the pallas quant backend the
mesh is *negotiated* per GEMM: each quantized matmul that the mesh can tile
runs the fused kernel shard-mapped (:mod:`repro.dist.shard_gemm`,
bit-identical to unsharded); GEMMs the mesh cannot tile fall back to XLA
with a logged reason — capability negotiation, not a hard error.

Execution policy (backend / tuning table / force_mode) is configured with
``context=`` (an :class:`repro.core.context.ExecContext`); the engine
installs ``context.tuning_table`` before building its jits, so every
quantized GEMM the model traces resolves through the table-backed
``select_plan`` (DESIGN.md §10; numerics pinned — a table changes speed,
never tokens).  The legacy ``quant_backend=`` / ``tuning_table=`` kwargs
keep working behind a ``DeprecationWarning`` (DESIGN.md §12).
"""
from __future__ import annotations

import contextlib
import logging
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh

from repro.core.context import ExecContext, resolve_context
from repro.dist import sharding as dist_sharding
from repro.models import lm
from repro.models.config import ModelConfig

log = logging.getLogger("repro.serve")

Params = Any

MIN_BUCKET = 8


def prompt_buckets_for(max_seq: int,
                       min_bucket: int = MIN_BUCKET) -> Tuple[int, ...]:
    """Default prompt-bucket ladder: powers of two up to ``max_seq``.

    Shared with ``python -m repro.tune --shapes serve`` so the tuner sweeps
    exactly the prefill shapes the engine will execute.
    """
    buckets = []
    b = min_bucket
    while b < max_seq:
        buckets.append(b)
        b *= 2
    buckets.append(max_seq)
    return tuple(sorted(set(buckets)))


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    stop_tokens: Tuple[int, ...] = ()
    generated: List[int] = field(default_factory=list)
    stats: Optional["RequestStats"] = None


@dataclass
class RequestStats:
    rid: int
    prompt_len: int
    arrival_s: float
    first_token_s: float = 0.0
    finish_s: float = 0.0
    n_tokens: int = 0
    stop_reason: str = ""

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0          # batched engine steps
    generated_tokens: int = 0      # actual tokens produced across requests
    requests: List[RequestStats] = field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        """Serving throughput: *generated tokens* (counting every request in
        flight — not engine steps) over total model time.  First tokens are
        produced by prefill, so the denominator includes prefill_s; a
        max_new_tokens=1 workload therefore still reports real throughput."""
        busy = self.prefill_s + self.decode_s
        return self.generated_tokens / busy if busy else 0.0


class _Slot:
    __slots__ = ("req", "pos", "last_tok", "rid", "n_tokens")

    def __init__(self):
        self.req: Optional[Request] = None
        self.pos = 0          # next cache write index
        self.last_tok = 0
        self.rid = 0
        self.n_tokens = 0     # tokens generated so far (sampling-key index)

    @property
    def active(self) -> bool:
        return self.req is not None


class Engine:
    """Continuous-batching engine over ``batch_size`` decode slots."""

    def __init__(self, cfg: ModelConfig, params: Params, max_seq: int = 512,
                 batch_size: int = 4, rng_seed: int = 0,
                 mesh: Optional[Mesh] = None,
                 prompt_buckets: Optional[Sequence[int]] = None,
                 tuning_table: Optional[Any] = None,
                 quant_backend: Optional[str] = None,
                 context: Optional[ExecContext] = None):
        if cfg.is_encdec:
            raise NotImplementedError(
                "continuous batching does not support encoder-decoder models")
        # Resolve the execution context.  Historical default: the model
        # config's own quant policy.  ``mesh=`` stays a first-class kwarg
        # (it also drives param/cache sharding, not just GEMMs) and is
        # folded into the context below.
        ctx = resolve_context(
            context, what="Engine", backend=quant_backend,
            tuning_table=tuning_table,
            _defaults=ExecContext(
                backend=getattr(cfg.quant, "backend", "xla"),
                force_mode=getattr(cfg.quant, "force_mode", "auto")))
        if mesh is not None:
            if ctx.mesh is not None and ctx.mesh is not mesh:
                raise ValueError("Engine: mesh= and context.mesh disagree; "
                                 "set one of them")
            ctx = ctx.replace(mesh=mesh)
        mesh = ctx.mesh
        if (ctx.backend != getattr(cfg.quant, "backend", "xla")
                or ctx.force_mode != getattr(cfg.quant, "force_mode", "auto")):
            # Rewrite the model's quantized-GEMM policy before any jit
            # traces: "pallas" serves through the fused single-pass kernel
            # (digit split + zero-point correction + dequant epilogue in one
            # pallas_call, DESIGN.md §11), "xla" through plain dot_generals.
            import dataclasses
            cfg = cfg.with_quant(dataclasses.replace(
                cfg.quant, backend=ctx.backend, force_mode=ctx.force_mode))
        if mesh is not None and getattr(cfg.quant, "backend", "xla") == "pallas":
            # Sharded pallas serving: each quantized GEMM the mesh can tile
            # runs the fused kernel shard-mapped (bit-identical to the
            # unsharded kernel); the rest fall back to XLA with a logged
            # per-GEMM reason (repro.dist.shard_gemm capability negotiation).
            log.info("serving with pallas quant backend under mesh %s: "
                     "GEMMs run shard-mapped where the mesh tiles them, "
                     "XLA otherwise (see repro.dist logs)", mesh)
        if ctx.tuning_table is not None:
            # Installs the PROCESS-GLOBAL registry before any jit below
            # traces (jit caches keep the plans active at trace time).
            # A context without a table leaves whatever table is currently
            # active untouched — to serve untuned after a tuned engine in
            # the same process, call repro.tune.set_active_table(None)
            # first (tables are numerics-pinned, so this only ever changes
            # speed, never tokens).
            from repro.tune import set_active_table
            set_active_table(ctx.tuning_table)
        self.context = ctx
        self.cfg = cfg
        self.mesh = mesh
        if mesh is not None:
            params = jax.device_put(
                params, dist_sharding.param_sharding(params, mesh))
        self.params = params
        self.max_seq = max_seq
        self.batch = batch_size
        self._key = jax.random.PRNGKey(rng_seed)
        if prompt_buckets is None:
            prompt_buckets = prompt_buckets_for(max_seq)
        self.prompt_buckets = tuple(sorted(set(prompt_buckets)))

        self._slots = [_Slot() for _ in range(batch_size)]
        self._pending: deque = deque()       # (req, arrival_s)
        self._next_rid = 0
        self._clock0 = time.monotonic()
        self._stats = ServeStats()

        with self._mesh_ctx():
            self._cache = self._make_cache(batch_size)
            # reusable zero-initialized batch-1 cache fed to every prefill
            # (never donated, so it stays zero)
            self._cache1 = lm.init_cache(cfg, 1, max_seq)

        # Under a mesh, pin the cache output sharding to the canonical
        # cache rules: otherwise GSPMD may pick a different layout for the
        # decode/insert result than the input had, and the next call
        # retraces (and silently resharded every step).
        decode_out_sh = insert_out_sh = None
        if mesh is not None:
            cache_sh = dist_sharding.cache_sharding(
                jax.eval_shape(lambda: lm.init_cache(cfg, batch_size,
                                                     max_seq)),
                mesh, batch=batch_size)
            from jax.sharding import NamedSharding
            logits_sh = NamedSharding(mesh, dist_sharding.batch_spec(mesh))
            decode_out_sh = (logits_sh, cache_sh)
            insert_out_sh = cache_sh
        self._decode = jax.jit(
            lambda p, c, tok, t: lm.decode_step(p, cfg, tok, c, t),
            donate_argnums=(1,), out_shardings=decode_out_sh)
        self._insert = jax.jit(
            lambda big, small, slot: jax.tree.map(
                lambda bl, sl: lax.dynamic_update_slice_in_dim(
                    bl, sl.astype(bl.dtype), slot, axis=1), big, small),
            donate_argnums=(0,), out_shardings=insert_out_sh)
        def prefill(p, cache1, toks, last):
            iota = jnp.arange(toks.shape[1], dtype=jnp.int32)[None, :]
            mask = iota <= last[:, None]
            logits, cache1, _ = lm.prefill(p, cfg, toks, cache1,
                                           pad_mask=mask, last_idx=last)
            return logits, cache1

        # one jitted prefill: jax.jit's shape-keyed cache gives exactly one
        # trace per prompt bucket
        self._prefill = jax.jit(prefill)
        self._sample = jax.jit(self._sample_fn)
        self._admitted_done: List[Request] = []

    # -- infrastructure -----------------------------------------------------

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _make_cache(self, b: int):
        cache = lm.init_cache(self.cfg, b, self.max_seq)
        if self.mesh is not None:
            cache = jax.device_put(
                cache,
                dist_sharding.cache_sharding(cache, self.mesh, batch=b))
        return cache

    def _now(self) -> float:
        return time.monotonic() - self._clock0

    def _bucket(self, n: int) -> int:
        for b in self.prompt_buckets:
            if b >= n:
                return b
        raise ValueError(f"prompt length {n} exceeds max bucket "
                         f"{self.prompt_buckets[-1]}")

    def _sample_fn(self, key, logits, temps, rids, steps):
        def one(lg, tmp, rid, st):
            k = jax.random.fold_in(jax.random.fold_in(key, rid), st)
            scaled = lg.astype(jnp.float32) / jnp.maximum(tmp, 1e-6)
            sampled = jax.random.categorical(k, scaled)
            return jnp.where(tmp > 0, sampled.astype(jnp.int32),
                             jnp.argmax(lg).astype(jnp.int32))

        return jax.vmap(one)(logits, temps, rids, steps)

    def n_traces(self) -> Dict[str, int]:
        """Compiled-trace counts (retrace monitoring for the serve bench);
        -1 per entry if the jax version doesn't expose cache sizes."""

        def size(fn) -> int:
            get = getattr(fn, "_cache_size", None)
            return int(get()) if callable(get) else -1

        return {
            "decode": size(self._decode),
            "prefill": size(self._prefill),
            "insert": size(self._insert),
        }

    # -- scheduling ---------------------------------------------------------

    def submit(self, req: Request, arrival_s: Optional[float] = None):
        """Enqueue a request; it is admitted when a slot frees up."""
        if req.max_new_tokens < 1:
            # the first token is sampled from the prefill logits at
            # admission, so a zero budget cannot be honored
            raise ValueError("max_new_tokens must be >= 1")
        if len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt({len(req.prompt)}) + max_new({req.max_new_tokens}) "
                f"exceeds max_seq={self.max_seq}")
        if len(req.prompt) > self.prompt_buckets[-1]:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds max prompt "
                f"bucket {self.prompt_buckets[-1]}")
        rid = self._next_rid
        self._next_rid += 1
        req.stats = RequestStats(
            rid=rid, prompt_len=len(req.prompt),
            arrival_s=self._now() if arrival_s is None else arrival_s)
        req.generated = []
        self._pending.append(req)

    @property
    def num_active(self) -> int:
        return sum(1 for s in self._slots if s.active)

    @property
    def num_pending(self) -> int:
        return len(self._pending)

    def _finish(self, slot: _Slot, reason: str):
        req = slot.req
        req.stats.finish_s = self._now()
        req.stats.n_tokens = len(req.generated)
        req.stats.stop_reason = reason
        self._stats.requests.append(req.stats)
        slot.req = None

    def _check_done(self, slot: _Slot, tok: int) -> Optional[str]:
        req = slot.req
        if tok in req.stop_tokens:
            return "stop_token"
        if len(req.generated) >= req.max_new_tokens:
            return "length"
        if slot.pos >= self.max_seq:
            return "max_seq"
        return None

    def _admit_one(self, slot_idx: int, req: Request):
        """Prefill a request into a free slot; samples its first token."""
        slot = self._slots[slot_idx]
        plen = len(req.prompt)
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt                       # right-pad
        last = np.array([plen - 1], np.int32)
        stats = self._stats
        with self._mesh_ctx():
            t0 = time.monotonic()
            logits, cache1 = self._prefill(
                self.params, self._cache1, jnp.asarray(toks),
                jnp.asarray(last))
            self._cache = self._insert(self._cache, cache1,
                                       jnp.int32(slot_idx))
            tok = self._sample(
                self._key, logits,
                jnp.asarray([req.temperature], jnp.float32),
                jnp.asarray([req.stats.rid], jnp.int32),
                jnp.asarray([0], jnp.int32))
            tok = int(np.asarray(tok)[0])
            stats.prefill_s += time.monotonic() - t0
        slot.req = req
        slot.pos = plen
        slot.last_tok = tok
        slot.rid = req.stats.rid
        slot.n_tokens = 1
        req.generated.append(tok)
        req.stats.first_token_s = self._now()
        stats.generated_tokens += 1
        reason = self._check_done(slot, tok)
        if reason is not None:      # e.g. max_new_tokens=1 or instant EOS
            self._finish(slot, reason)
            self._admitted_done.append(req)

    def _admit(self):
        while self._pending:
            if self._pending[0].stats.arrival_s > self._now():
                break                     # respects a future arrival trace
            free = next((i for i, s in enumerate(self._slots)
                         if not s.active), None)
            if free is None:
                break
            self._admit_one(free, self._pending.popleft())

    def step(self) -> List[Request]:
        """Admit what fits, then run one batched decode step.

        Returns the requests that finished during this step — including
        those that finished at admission (first prefill token hit EOS or a
        1-token budget)."""
        self._admit()
        finished: List[Request] = self._admitted_done
        self._admitted_done = []
        active = [s for s in self._slots if s.active]
        if not active:
            return finished
        toks = np.array([s.last_tok for s in self._slots], np.int32)
        # park inactive slots at their current position (their lane still
        # computes, but writes land in a dead slot that admission overwrites)
        pos = np.array([min(s.pos, self.max_seq - 1) for s in self._slots],
                       np.int32)
        temps = np.array(
            [s.req.temperature if s.active else 0.0 for s in self._slots],
            np.float32)
        rids = np.array([s.rid for s in self._slots], np.int32)
        steps = np.array([s.n_tokens for s in self._slots], np.int32)
        stats = self._stats
        t0 = time.monotonic()
        with self._mesh_ctx():
            logits, self._cache = self._decode(
                self.params, self._cache, jnp.asarray(toks), jnp.asarray(pos))
            nxt = np.asarray(self._sample(
                self._key, logits, jnp.asarray(temps), jnp.asarray(rids),
                jnp.asarray(steps)))
        stats.decode_s += time.monotonic() - t0
        stats.decode_steps += 1
        for i, slot in enumerate(self._slots):
            if not slot.active:
                continue
            tok = int(nxt[i])
            slot.pos += 1
            slot.last_tok = tok
            slot.n_tokens += 1
            slot.req.generated.append(tok)
            stats.generated_tokens += 1
            reason = self._check_done(slot, tok)
            if reason is not None:
                req = slot.req
                self._finish(slot, reason)
                finished.append(req)
        return finished

    # -- batch driver -------------------------------------------------------

    def generate(self, requests: List[Request],
                 arrival_s: Optional[Sequence[float]] = None) -> ServeStats:
        """Serve ``requests`` to completion; fills ``req.generated`` and
        returns the run's :class:`ServeStats`.

        ``arrival_s`` (optional, seconds relative to now) replays an arrival
        trace: a request is only admitted once its arrival time has passed
        (TTFT then includes queueing delay)."""
        self._stats = ServeStats()
        self._clock0 = time.monotonic()
        if arrival_s is None:
            for r in requests:
                self.submit(r)
        else:
            order = sorted(range(len(requests)), key=lambda i: arrival_s[i])
            for i in order:
                self.submit(requests[i], arrival_s=float(arrival_s[i]))
        while self._pending or self.num_active:
            if not self.num_active and self._pending:
                wait = self._pending[0].stats.arrival_s - self._now()
                if wait > 0:
                    time.sleep(min(wait, 0.01))
            self.step()
        return self._stats
