"""Continuous-batching serve engine: orchestrator over scheduler / cache /
executor layers.

The engine used to be a monolith owning scheduling state, the dense slot
cache and every jit.  It is now wiring between three seams:

  * :mod:`repro.serve.scheduler` — admission + step policy.  Decode runs on
    the smallest power-of-two *bucketed* live-slot batch (one trace per
    bucket width), so a 64-slot engine with 3 live requests pays for a
    4-wide decode, not 64 — the slot-scaling cliff fix.  Long prompts
    prefill in fixed-size chunks interleaved between decode steps
    (``prefill_chunk=``), so TTFT of concurrent requests stops being
    hostage to the longest prompt.
  * :mod:`repro.serve.cache` — paged KV / recurrent-state pool (fixed-size
    pages, slot→page table as a jit-visible int32 array) with optional
    prompt-prefix sharing (``prefix_cache=True``): repeated prompt prefixes
    restore a page/state snapshot instead of recomputing, bit-exact vs a
    cold prefill.
  * :mod:`repro.serve.executor` — the compiled gather/compute/scatter entry
    points over the pool, riding the existing ``ExecContext`` execution
    path (quantized KMM policy, optional mesh, tuning tables).

Correctness on ragged prompts is unchanged from the dense-cache engine:
prompts are right-padded to bucket widths with ``pad_mask``/``last_idx``
threaded into :func:`repro.models.lm.prefill` (now with a resume offset
``start=`` for chunking), and decode runs a per-slot position vector.
Admission order and per-(request, step) sampling keys make output
token-identical to sequential single-request generation — independent of
slot count, decode-bucket width, prefill chunking and prefix-cache hits.

Pass ``mesh=`` to serve sharded: params take the ``repro.dist.sharding``
param rules, the page pools take the page-pool rules (pages over ``data``,
kv-heads over ``model``), and the executor's jits run under the mesh so
GSPMD partitions them (DESIGN.md §4.3, §13).  With the pallas quant
backend the mesh is *negotiated* per GEMM (:mod:`repro.dist.shard_gemm`).

Execution policy (backend / tuning table / force_mode) is configured with
``context=`` (an :class:`repro.core.context.ExecContext`); the legacy
``quant_backend=`` / ``tuning_table=`` kwargs keep working behind a
``DeprecationWarning`` (DESIGN.md §12).
"""
from __future__ import annotations

import contextlib
import logging
import math
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.context import ExecContext, resolve_context
from repro.dist import sharding as dist_sharding
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.cache import PagedCachePool, PrefixCache, default_page_size
from repro.serve.executor import Executor
from repro.serve.scheduler import (MIN_BUCKET, Request, RequestStats,
                                   Scheduler, ServeStats, SlotState,
                                   prompt_buckets_for)

__all__ = ["Engine", "Request", "RequestStats", "ServeStats", "SlotState",
           "prompt_buckets_for", "MIN_BUCKET"]

log = logging.getLogger("repro.serve")

Params = Any

# Serve-path instruments (DESIGN.md §14).  All observations happen in host
# Python around the executor's compiled calls — never inside them — so
# enabling metrics/tracing cannot change a sampled token; disabled (the
# default) each site costs a flag test.
_TTFT = obs_metrics.histogram(
    "repro_serve_ttft_seconds", "arrival to first token, per request")
_DECODE_STEP = obs_metrics.histogram(
    "repro_serve_decode_step_seconds", "wall time of one bucketed decode step")
_OCCUPANCY = obs_metrics.gauge(
    "repro_serve_occupancy", "live slots / total slots at the last decode step")
_FINISHED = obs_metrics.counter(
    "repro_serve_finished_total", "finished requests by stop reason",
    labels=("reason",))


class Engine:
    """Continuous-batching engine over ``batch_size`` decode slots."""

    def __init__(self, cfg, params: Params, max_seq: int = 512,
                 batch_size: int = 4, rng_seed: int = 0,
                 mesh: Optional[Mesh] = None,
                 prompt_buckets: Optional[Sequence[int]] = None,
                 tuning_table: Optional[Any] = None,
                 quant_backend: Optional[str] = None,
                 context: Optional[ExecContext] = None,
                 page_size: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 prefix_snapshots: int = 4):
        if cfg.is_encdec:
            raise NotImplementedError(
                "continuous batching does not support encoder-decoder models")
        # Resolve the execution context.  Historical default: the model
        # config's own quant policy.  ``mesh=`` stays a first-class kwarg
        # (it also drives param/cache sharding, not just GEMMs) and is
        # folded into the context below.
        ctx = resolve_context(
            context, what="Engine", backend=quant_backend,
            tuning_table=tuning_table,
            _defaults=ExecContext(
                backend=getattr(cfg.quant, "backend", "xla"),
                force_mode=getattr(cfg.quant, "force_mode", "auto")))
        if mesh is not None:
            if ctx.mesh is not None and ctx.mesh is not mesh:
                raise ValueError("Engine: mesh= and context.mesh disagree; "
                                 "set one of them")
            ctx = ctx.replace(mesh=mesh)
        mesh = ctx.mesh
        if (ctx.backend != getattr(cfg.quant, "backend", "xla")
                or ctx.force_mode != getattr(cfg.quant, "force_mode", "auto")):
            # Rewrite the model's quantized-GEMM policy before any jit
            # traces: "pallas" serves through the fused single-pass kernel
            # (DESIGN.md §11), "xla" through plain dot_generals.
            import dataclasses
            cfg = cfg.with_quant(dataclasses.replace(
                cfg.quant, backend=ctx.backend, force_mode=ctx.force_mode))
        if mesh is not None and getattr(cfg.quant, "backend", "xla") == "pallas":
            log.info("serving with pallas quant backend under mesh %s: "
                     "GEMMs run shard-mapped where the mesh tiles them, "
                     "XLA otherwise (see repro.dist logs)", mesh)
        if ctx.tuning_table is not None:
            # Installs the PROCESS-GLOBAL registry before any jit below
            # traces (jit caches keep the plans active at trace time).
            # Tables are numerics-pinned: a table changes speed, never
            # tokens (DESIGN.md §10).
            from repro.tune import set_active_table
            set_active_table(ctx.tuning_table)
        self.context = ctx
        self.cfg = cfg
        self.mesh = mesh
        if mesh is not None:
            params = jax.device_put(
                params, dist_sharding.param_sharding(params, mesh))
        self.params = params
        self.max_seq = max_seq
        self.batch = batch_size
        self._key = jax.random.PRNGKey(rng_seed)
        if prompt_buckets is None:
            prompt_buckets = prompt_buckets_for(max_seq)
        self.prompt_buckets = tuple(sorted(set(prompt_buckets)))

        # -- chunked prefill / paging knobs ---------------------------------
        if page_size is None:
            page_size = default_page_size(max_seq)
        if max_seq % page_size:
            raise ValueError(f"page_size={page_size} must divide "
                             f"max_seq={max_seq}")
        if prefix_cache and prefill_chunk is None:
            # prefix restore resumes prefill mid-prompt, which needs the
            # chunked entry; pick a chunk covering at least one page
            prefill_chunk = max(page_size, MIN_BUCKET)
        if prefill_chunk is not None:
            if prefill_chunk < MIN_BUCKET or \
                    prefill_chunk & (prefill_chunk - 1):
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} must be a power of two "
                    f">= {MIN_BUCKET} (the serve mamba-scan grid)")
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self._chunk_buckets = (prompt_buckets_for(prefill_chunk)
                               if prefill_chunk is not None else None)

        self.scheduler = Scheduler(batch_size, max_seq)
        self.pool = PagedCachePool(
            cfg, batch_size, max_seq, page_size,
            snapshot_slots=prefix_snapshots if prefix_cache else 0,
            mesh=mesh)
        self.executor = Executor(cfg, self.params, self.pool, mesh=mesh)
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            align = math.lcm(page_size, prefill_chunk, MIN_BUCKET)
            self.prefix = PrefixCache(self.pool, align)

        self._next_rid = 0
        self._clock0 = time.monotonic()
        self._stats = ServeStats()
        self._admitted_done: List[Request] = []

    # -- infrastructure -----------------------------------------------------

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _now(self) -> float:
        return time.monotonic() - self._clock0

    def _bucket(self, n: int, buckets: Sequence[int]) -> int:
        for b in buckets:
            if b >= n:
                return b
        raise ValueError(f"prompt length {n} exceeds max bucket "
                         f"{buckets[-1]}")

    def n_traces(self) -> Dict[str, int]:
        """Compiled-trace counts (retrace monitoring for the serve bench);
        -1 per entry if the jax version doesn't expose cache sizes.
        ``decode`` counts one trace per decode-bucket width."""
        return self.executor.n_traces()

    def warm(self):
        """Pre-trace every decode-bucket width and prefill chunk/bucket
        width so a measured run sees steady-state traces.  Warm calls run
        on the pool's parking rows only — no slot state is touched — and
        must happen while the engine is idle."""
        if self.scheduler.num_active or self.scheduler.num_pending:
            raise RuntimeError("warm() requires an idle engine")
        with self._mesh_ctx():
            for w in self.scheduler.decode_widths:
                lanes = [None] * w
                z = np.zeros((w,), np.int32)
                logits = self.executor.decode(lanes, z, z)
                self.executor.sample(self._key, logits,
                                     np.zeros((w,), np.float32), z, z)
            widths = self._chunk_buckets or self.prompt_buckets
            for w in widths:
                toks = np.zeros((1, w), np.int32)
                last = np.array([w - 1], np.int32)
                logits = self.executor.prefill(None, toks, 0, last)
                self.executor.sample(self._key, logits,
                                     np.zeros((1,), np.float32),
                                     np.zeros((1,), np.int32),
                                     np.zeros((1,), np.int32))

    # -- scheduling ---------------------------------------------------------

    def submit(self, req: Request, arrival_s: Optional[float] = None):
        """Enqueue a request; it is admitted when a slot frees up."""
        if req.max_new_tokens < 1:
            # the first token is sampled from the prefill logits at
            # admission, so a zero budget cannot be honored
            raise ValueError("max_new_tokens must be >= 1")
        if len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt({len(req.prompt)}) + max_new({req.max_new_tokens}) "
                f"exceeds max_seq={self.max_seq}")
        if self.prefill_chunk is None \
                and len(req.prompt) > self.prompt_buckets[-1]:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds max prompt "
                f"bucket {self.prompt_buckets[-1]}")
        rid = self._next_rid
        self._next_rid += 1
        req.stats = RequestStats(
            rid=rid, prompt_len=len(req.prompt),
            arrival_s=self._now() if arrival_s is None else arrival_s)
        req.generated = []
        obs_trace.begin_async("request", rid, prompt_len=len(req.prompt),
                              max_new=req.max_new_tokens)
        self.scheduler.enqueue(req)

    @property
    def num_active(self) -> int:
        return self.scheduler.num_active

    @property
    def num_pending(self) -> int:
        return self.scheduler.num_pending

    def _finish(self, idx: int, reason: str):
        slot = self.scheduler.slots[idx]
        req = slot.req
        req.stats.finish_s = self._now()
        req.stats.n_tokens = len(req.generated)
        req.stats.stop_reason = reason
        _FINISHED.inc(reason)
        obs_trace.end_async("request", req.stats.rid, reason=reason,
                            n_tokens=req.stats.n_tokens)
        self._stats.requests.append(req.stats)
        self.scheduler.finish(idx)

    def _check_done(self, slot: SlotState, tok: int) -> Optional[str]:
        req = slot.req
        if tok in req.stop_tokens:
            return "stop_token"
        if len(req.generated) >= req.max_new_tokens:
            return "length"
        if slot.pos >= self.max_seq:
            return "max_seq"
        return None

    # -- prefill ------------------------------------------------------------

    def _init_slot(self, idx: int, req: Request):
        """Initialize an admitted slot's pool rows and prefill plan."""
        slot = self.scheduler.slots[idx]
        with self._mesh_ctx():
            self.pool.zero_slot_state(idx)
            if self.prefix is not None:
                slot.prefill.snap_at = self.prefix.boundary_for(
                    len(req.prompt))
                hit_len, hit = self.prefix.lookup(req.prompt)
                if hit:
                    self.prefix.restore(idx, req.prompt, hit_len)
                    slot.prefill.off = hit_len
                    slot.prefill.from_prefix = True

    def _run_prefill_chunk(self, idx: int) -> Optional[Request]:
        """Advance one slot's prefill by one chunk (the whole remaining
        prompt when chunking is off).  Returns the request if it finished
        at admission (1-token budget or instant EOS)."""
        slot = self.scheduler.slots[idx]
        req, ps = slot.req, slot.prefill
        plen = len(req.prompt)
        if self.prefill_chunk is None:
            take = plen - ps.off
            width = self._bucket(take, self.prompt_buckets)
        else:
            take = min(self.prefill_chunk, plen - ps.off)
            width = self._bucket(take, self._chunk_buckets)
        toks = np.zeros((1, width), np.int32)
        toks[0, :take] = req.prompt[ps.off:ps.off + take]   # right-pad
        last = np.array([take - 1], np.int32)
        stats = self._stats
        with self._mesh_ctx():
            t0 = time.monotonic()
            with obs_trace.span("prefill_chunk", slot=idx,
                                rid=req.stats.rid, off=ps.off, width=width):
                logits = self.executor.prefill(idx, toks, ps.off, last)
                if ps.off + take < plen:
                    jax.block_until_ready(logits)
            stats.prefill_s += time.monotonic() - t0
            ps.off += take
            if self.prefix is not None and ps.off == ps.snap_at \
                    and ps.snap_at > 0:
                self.prefix.store(idx, req.prompt, ps.snap_at)
            if ps.off < plen:
                return None
            # prompt complete: sample the first token from the last chunk's
            # last-real-position logits
            tok = int(np.asarray(self.executor.sample(
                self._key, logits,
                np.asarray([req.temperature], np.float32),
                np.asarray([req.stats.rid], np.int32),
                np.asarray([0], np.int32)))[0])
        self.scheduler.prefill_done(idx, tok)
        req.generated.append(tok)
        req.stats.first_token_s = self._now()
        _TTFT.observe(req.stats.ttft_s)
        stats.generated_tokens += 1
        reason = self._check_done(slot, tok)
        if reason is not None:      # e.g. max_new_tokens=1 or instant EOS
            self._finish(idx, reason)
            return req
        return None

    def _prefill_step(self):
        """Prefill policy for one engine step: with chunking off, complete
        every admitted prompt (admission-time prefill, the dense-engine
        behavior); with chunking on, advance one prefilling slot by one
        chunk so prompts interleave with decode steps."""
        if self.prefill_chunk is None:
            for idx in self.scheduler.prefilling():
                req = self._run_prefill_chunk(idx)
                if req is not None:
                    self._admitted_done.append(req)
        else:
            idxs = self.scheduler.prefilling()
            if idxs:
                req = self._run_prefill_chunk(idxs[0])
                if req is not None:
                    self._admitted_done.append(req)

    # -- decode -------------------------------------------------------------

    def _decode_step(self) -> List[Request]:
        n_live, lanes = self.scheduler.decode_lanes()
        if not n_live:
            return []
        slots = self.scheduler.slots
        toks = np.array([slots[j].last_tok if j is not None else 0
                         for j in lanes], np.int32)
        # park free/padding lanes at a harmless position (their writes land
        # in dead slot rows or the pool's parking rows)
        pos = np.array([min(slots[j].pos, self.max_seq - 1)
                        if j is not None else 0 for j in lanes], np.int32)
        temps = np.array([slots[j].req.temperature
                          if j is not None and slots[j].decoding else 0.0
                          for j in lanes], np.float32)
        rids = np.array([slots[j].rid if j is not None else 0
                         for j in lanes], np.int32)
        steps = np.array([slots[j].n_tokens if j is not None else 0
                          for j in lanes], np.int32)
        stats = self._stats
        t0 = time.monotonic()
        with self._mesh_ctx():
            with obs_trace.span("decode_step", n_live=n_live,
                                width=len(lanes)):
                logits = self.executor.decode(lanes, toks, pos)
                nxt = np.asarray(self.executor.sample(
                    self._key, logits, temps, rids, steps))
        dt = time.monotonic() - t0
        stats.decode_s += dt
        stats.decode_steps += 1
        stats.occupancy_sum += n_live / self.batch
        _DECODE_STEP.observe(dt)
        _OCCUPANCY.set(n_live / self.batch)
        finished: List[Request] = []
        for lane, idx in enumerate(lanes[:n_live]):     # live lanes first
            slot = slots[idx]
            tok = int(nxt[lane])
            slot.pos += 1
            slot.last_tok = tok
            slot.n_tokens += 1
            slot.req.generated.append(tok)
            stats.generated_tokens += 1
            reason = self._check_done(slot, tok)
            if reason is not None:
                req = slot.req
                self._finish(idx, reason)
                finished.append(req)
        return finished

    # -- step / driver ------------------------------------------------------

    def step(self) -> List[Request]:
        """Admit what fits, advance prefill, then run one bucketed decode
        step.  Returns the requests that finished during this step —
        including those that finished at admission (first prefill token hit
        EOS or a 1-token budget)."""
        t0 = time.monotonic()
        with obs_trace.span("engine_step"):
            for idx, req in self.scheduler.admit(self._now()):
                self._init_slot(idx, req)
            self._prefill_step()
            finished = self._admitted_done
            self._admitted_done = []
            finished += self._decode_step()
        self._stats.busy_s += time.monotonic() - t0
        return finished

    def generate(self, requests: List[Request],
                 arrival_s: Optional[Sequence[float]] = None) -> ServeStats:
        """Serve ``requests`` to completion; fills ``req.generated`` and
        returns the run's :class:`ServeStats`.

        ``arrival_s`` (optional, seconds relative to now) replays an arrival
        trace: a request is only admitted once its arrival time has passed
        (TTFT then includes queueing delay)."""
        self._stats = ServeStats()
        self._clock0 = time.monotonic()
        if arrival_s is None:
            for r in requests:
                self.submit(r)
        else:
            order = sorted(range(len(requests)), key=lambda i: arrival_s[i])
            for i in order:
                self.submit(requests[i], arrival_s=float(arrival_s[i]))
        sched = self.scheduler
        while sched.num_pending or sched.num_active:
            if not sched.num_active and sched.num_pending:
                wait = sched.next_arrival_s - self._now()
                if wait > 0:
                    time.sleep(min(wait, 0.01))
            self.step()
        return self._stats
