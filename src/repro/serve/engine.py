"""Batched serving engine: prefill + decode with KV/recurrent caches.

A minimal-but-real continuous-batching engine: requests are padded into a
fixed batch, prefilled once, then decoded step-by-step with greedy or
temperature sampling.  All matmuls ride the model's quantized KMM policy —
this is the paper's deployment scenario (integer inference accelerator).

Pass ``mesh=`` to serve sharded: params take the ``repro.dist.sharding``
param rules, the per-group decode cache takes the cache rules (batch over
``data``, kv-heads over ``model``), and prefill/decode jits run under the
mesh so GSPMD partitions them (DESIGN.md §4.3).
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.dist import sharding as dist_sharding
from repro.models import lm
from repro.models.config import ModelConfig

Params = Any


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: List[int] = field(default_factory=list)


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.decode_steps / self.decode_s if self.decode_s else 0.0


class Engine:
    def __init__(self, cfg: ModelConfig, params: Params, max_seq: int = 512,
                 batch_size: int = 4, rng_seed: int = 0,
                 mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.mesh = mesh
        if mesh is not None:
            params = jax.device_put(
                params, dist_sharding.param_sharding(params, mesh))
        self.params = params
        self.max_seq = max_seq
        self.batch = batch_size
        self.key = jax.random.PRNGKey(rng_seed)
        self._decode = jax.jit(
            lambda p, c, tok, t, mem: lm.decode_step(p, cfg, tok, c, t, mem=mem))
        self._prefill = jax.jit(
            lambda p, c, toks: lm.prefill(p, cfg, toks, c))

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _make_cache(self, b: int):
        cache = lm.init_cache(self.cfg, b, self.max_seq)
        if self.mesh is not None:
            cache = jax.device_put(
                cache,
                dist_sharding.cache_sharding(cache, self.mesh, batch=b))
        return cache

    def generate(self, requests: List[Request]) -> ServeStats:
        cfg = self.cfg
        stats = ServeStats()
        for group_start in range(0, len(requests), self.batch):
            group = requests[group_start:group_start + self.batch]
            self._generate_group(group, stats)
        return stats

    def _generate_group(self, group: List[Request], stats: ServeStats):
        cfg = self.cfg
        b = len(group)
        plen = max(len(r.prompt) for r in group)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(group):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        cache = self._make_cache(b)
        with self._mesh_ctx():
            t0 = time.time()
            logits, cache, mem = self._prefill(self.params, cache,
                                               jnp.asarray(toks))
            logits.block_until_ready()
            stats.prefill_s += time.time() - t0
            max_new = max(r.max_new_tokens for r in group)
            pos = plen
            t0 = time.time()
            for step in range(max_new):
                next_tok = self._sample(logits, group)
                for i, r in enumerate(group):
                    if step < r.max_new_tokens:
                        r.generated.append(int(next_tok[i]))
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(next_tok),
                                             jnp.int32(pos), mem)
                pos += 1
                stats.decode_steps += 1
            jax.block_until_ready(logits)
            stats.decode_s += time.time() - t0

    def _sample(self, logits: jax.Array, group: List[Request]) -> np.ndarray:
        temps = np.array([r.temperature for r in group], np.float32)
        if (temps == 0).all():
            return np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        self.key, sub = jax.random.split(self.key)
        scaled = logits / jnp.maximum(jnp.asarray(temps)[:, None], 1e-6)
        sampled = jax.random.categorical(sub, scaled, axis=-1)
        greedy = jnp.argmax(logits, -1)
        out = jnp.where(jnp.asarray(temps) > 0, sampled, greedy)
        return np.asarray(out).astype(np.int32)
