"""Admission + step policy for the continuous-batching serve engine.

Pure host-side scheduling state: which request sits in which decode slot,
which slots are mid-(chunked-)prefill, and how a decode step is batched.
No jax in this module — the engine wires the scheduler's decisions into
the executor's jits, and tests drive scheduling through this API instead
of poking engine internals.

Bucketed decode (the slot-scaling-cliff fix): decode runs on the smallest
power-of-two *slot bucket* that covers the live slots — the same ladder
shape as the prompt buckets, anchored at 1 (``decode_widths_for``).  A
64-slot engine with 3 live requests decodes a 4-wide batch; the lanes
padding a bucket are distinct *free* slots first (their pool rows are dead
and admission re-initializes them) and the pool's parking rows after that,
so padded lanes can never alias a live slot, a mid-prefill slot, or a
prefix snapshot.  One decode trace per bucket width.

Chunked prefill: an admitted request holds its slot in a *prefilling*
state; each engine step advances every prefilling slot by one chunk, so
long prompts interleave with decode steps and TTFT of concurrent requests
stops being hostage to the longest prompt.  Slots are decodable only once
their prefill is complete.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.obs import metrics as obs_metrics

MIN_BUCKET = 8

# Host-side scheduler signals (this module stays jax-free; the metrics
# registry is pure stdlib).
_QUEUE_DEPTH = obs_metrics.gauge(
    "repro_serve_queue_depth", "pending requests awaiting a decode slot")
_ADMITTED = obs_metrics.counter(
    "repro_serve_admitted_total", "requests admitted into decode slots")


def prompt_buckets_for(max_seq: int,
                       min_bucket: int = MIN_BUCKET) -> Tuple[int, ...]:
    """Default prompt-bucket ladder: powers of two up to ``max_seq``.

    Shared with ``python -m repro.tune --shapes serve`` so the tuner sweeps
    exactly the prefill shapes the engine will execute.
    """
    buckets = []
    b = min_bucket
    while b < max_seq:
        buckets.append(b)
        b *= 2
    buckets.append(max_seq)
    return tuple(sorted(set(buckets)))


def decode_widths_for(n_slots: int) -> Tuple[int, ...]:
    """Decode-batch bucket ladder: the prompt ladder anchored at width 1."""
    return prompt_buckets_for(n_slots, min_bucket=1)


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    stop_tokens: Tuple[int, ...] = ()
    generated: List[int] = field(default_factory=list)
    stats: Optional["RequestStats"] = None


@dataclass
class RequestStats:
    rid: int
    prompt_len: int
    arrival_s: float
    first_token_s: float = 0.0
    finish_s: float = 0.0
    n_tokens: int = 0
    stop_reason: str = ""

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    busy_s: float = 0.0            # wall-clock span of engine activity
    decode_steps: int = 0          # batched engine steps
    generated_tokens: int = 0      # actual tokens produced across requests
    occupancy_sum: float = 0.0     # sum over decode steps of live/slots
    requests: List[RequestStats] = field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        """Serving throughput: *generated tokens* (counting every request in
        flight — not engine steps) over engine-busy wall-clock time.

        ``busy_s`` is the span the engine actually spent admitting,
        prefilling and decoding; once prefill chunks interleave with decode
        steps, ``prefill_s + decode_s`` would double-count overlapped work
        conceptually belonging to the same span.  Stats built by hand (no
        measured busy span) fall back to the legacy ``prefill_s +
        decode_s`` denominator so the old accounting keeps working."""
        busy = self.busy_s or (self.prefill_s + self.decode_s)
        return self.generated_tokens / busy if busy else 0.0

    @property
    def occupancy_pct(self) -> float:
        """Mean live-slot occupancy (%) across decode steps."""
        if not self.decode_steps:
            return 0.0
        return 100.0 * self.occupancy_sum / self.decode_steps


@dataclass
class _PrefillState:
    off: int = 0                    # next prompt offset to run
    snap_at: int = 0                # prefix-snapshot boundary (0: none)
    from_prefix: bool = False       # restored from a prefix-cache hit


class SlotState:
    """Per-slot scheduling state (no cache data — that lives in the pool)."""

    __slots__ = ("req", "pos", "last_tok", "rid", "n_tokens", "prefill")

    def __init__(self):
        self.req: Optional[Request] = None
        self.pos = 0          # next cache write index
        self.last_tok = 0
        self.rid = 0
        self.n_tokens = 0     # tokens generated so far (sampling-key index)
        self.prefill: Optional[_PrefillState] = None

    @property
    def active(self) -> bool:
        return self.req is not None

    @property
    def decoding(self) -> bool:
        return self.req is not None and self.prefill is None


class Scheduler:
    """Slot admission + step policy; owns no jax state."""

    def __init__(self, n_slots: int, max_seq: int):
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.decode_widths = decode_widths_for(n_slots)
        self.slots = [SlotState() for _ in range(n_slots)]
        self._pending: deque = deque()
        self._rr = 0    # round-robin cursor over prefilling slots

    # -- queue --------------------------------------------------------------

    def enqueue(self, req: Request):
        self._pending.append(req)
        _QUEUE_DEPTH.set(len(self._pending))

    @property
    def num_active(self) -> int:
        return sum(1 for s in self.slots if s.active)

    @property
    def num_pending(self) -> int:
        return len(self._pending)

    @property
    def next_arrival_s(self) -> Optional[float]:
        return self._pending[0].stats.arrival_s if self._pending else None

    def admit(self, now: float) -> List[Tuple[int, Request]]:
        """Move arrived pending requests into free slots (FIFO, respecting
        the arrival trace); returns ``(slot_idx, request)`` assignments.
        The engine initializes the slot's pool rows and prefill plan."""
        out: List[Tuple[int, Request]] = []
        while self._pending:
            if self._pending[0].stats.arrival_s > now:
                break
            free = next((i for i, s in enumerate(self.slots)
                         if not s.active), None)
            if free is None:
                break
            req = self._pending.popleft()
            slot = self.slots[free]
            slot.req = req
            slot.pos = 0
            slot.last_tok = 0
            slot.rid = req.stats.rid
            slot.n_tokens = 0
            slot.prefill = _PrefillState()
            out.append((free, req))
        if out:
            _ADMITTED.inc(by=len(out))
            _QUEUE_DEPTH.set(len(self._pending))
        return out

    # -- prefill ------------------------------------------------------------

    def prefilling(self) -> List[int]:
        """Slots mid-prefill, round-robin rotated so interleaved chunking
        shares engine steps fairly across concurrent prompts."""
        idxs = [i for i, s in enumerate(self.slots)
                if s.active and s.prefill is not None]
        if not idxs:
            return idxs
        r = self._rr % len(idxs)
        self._rr += 1
        return idxs[r:] + idxs[:r]

    def prefill_done(self, idx: int, first_token: int):
        """Transition a slot from prefilling to decoding."""
        slot = self.slots[idx]
        slot.prefill = None
        slot.pos = len(slot.req.prompt)
        slot.last_tok = first_token
        slot.n_tokens = 1

    # -- decode batching ----------------------------------------------------

    def decode_lanes(self) -> Tuple[int, List[Optional[int]]]:
        """Bucketed decode batch: ``(n_live, lanes)`` where ``lanes`` is the
        live slots padded to the smallest covering bucket width — first
        with distinct free slots (dead rows), then with ``None`` (the
        pool's parking rows).  Mid-prefill slots are never used as padding:
        their pool rows hold real partial state."""
        live = [i for i, s in enumerate(self.slots) if s.decoding]
        if not live:
            return 0, []
        width = next(w for w in self.decode_widths if w >= len(live))
        free = [i for i, s in enumerate(self.slots) if not s.active]
        lanes: List[Optional[int]] = list(live)
        lanes += free[:width - len(lanes)]
        lanes += [None] * (width - len(lanes))
        return len(live), lanes

    def finish(self, idx: int):
        self.slots[idx].req = None
        self.slots[idx].prefill = None
