"""Persisted per-(backend, M/N/K bucket, bitwidth) tuning tables.

A table maps a GEMM problem key to the measured-winner :class:`ExecPlan`
found by :mod:`repro.tune.runner`.  Tables are plain JSON under ``tuned/``
so they diff cleanly across PRs and load with zero dependencies:

    {
      "version": 1,
      "device": "cpu/interpret",
      "entries": {
        "pallas/m64/k128/n64/w12": {
          "variant": "kmm2", "block_m": 64, "block_n": 64, "block_k": 128,
          "combine_int32": false, "depth": 1, "us": 412.7,
          "us_default": 500.1, "n_candidates": 31
        }
      }
    }

Lookups bucket M/N/K to powers of two (``space.bucket_shape``), so one sweep
over the shape grid of ``python -m repro.tune`` covers every nearby runtime
shape.  The process-global *active table* is the registry the dispatch seam
(:func:`repro.core.dispatch.select_plan`) consults; install one with
``set_active_table(path_or_table)`` or scoped via ``use_table(...)``.
Install tables *before* tracing/jitting model code: jit caches hold the plan
that was active at trace time.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

from repro.core.dispatch import ExecPlan
from repro.tune.space import Shape, bucket_shape

TABLE_VERSION = 1
DEFAULT_DIR = "tuned"
DEFAULT_PATH = os.path.join(DEFAULT_DIR, "default.json")

_ENTRY_FIELDS = ("variant", "block_m", "block_n", "block_k",
                 "combine_int32", "depth")


def key_for(backend: str, shape: Shape, w: int, m: int = 8) -> str:
    """Table key; includes the multiplier bitwidth ``m`` so sweeps at
    different multiplier widths (different dispatch windows) never collide."""
    mb, kb, nb = bucket_shape(shape)
    return f"{backend}/m{mb}/k{kb}/n{nb}/w{w}/mult{m}"


@dataclass
class TuningTable:
    """In-memory tuning table; ``entries`` maps key -> plain-dict record."""

    entries: Dict[str, dict] = field(default_factory=dict)
    device: str = ""
    meta: Dict[str, str] = field(default_factory=dict)

    # -- queries ------------------------------------------------------------

    def lookup(self, backend: str, shape: Shape, w: int,
               m: int = 8) -> Optional[ExecPlan]:
        rec = self.entries.get(key_for(backend, shape, w, m))
        if rec is None:
            return None
        try:
            return ExecPlan(
                variant=str(rec["variant"]), w=w, m=m, backend=backend,
                block_m=int(rec["block_m"]), block_n=int(rec["block_n"]),
                block_k=int(rec["block_k"]),
                combine_int32=bool(rec["combine_int32"]),
                depth=int(rec.get("depth", 1)), source="table")
        except (KeyError, TypeError, ValueError):
            return None            # malformed entry: treat as missing

    def put(self, backend: str, shape: Shape, w: int, plan: ExecPlan,
            **extra) -> str:
        key = key_for(backend, shape, w, plan.m)
        rec = {f: getattr(plan, f) for f in _ENTRY_FIELDS}
        rec.update({k: v for k, v in extra.items() if v is not None})
        self.entries[key] = rec
        return key

    def __len__(self) -> int:
        return len(self.entries)

    # -- persistence --------------------------------------------------------

    def save(self, path: Union[str, os.PathLike]) -> None:
        d = os.path.dirname(str(path))
        if d:
            os.makedirs(d, exist_ok=True)
        doc = {"version": TABLE_VERSION, "device": self.device,
               "meta": self.meta,
               "entries": {k: self.entries[k] for k in sorted(self.entries)}}
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: Union[str, os.PathLike]) -> "TuningTable":
        with open(path) as f:
            doc = json.load(f)
        if int(doc.get("version", 0)) != TABLE_VERSION:
            raise ValueError(
                f"tuning table {path}: version {doc.get('version')!r} "
                f"unsupported (want {TABLE_VERSION})")
        entries = doc.get("entries", {})
        if not isinstance(entries, dict):
            raise ValueError(f"tuning table {path}: 'entries' must be a dict")
        return cls(entries=dict(entries), device=str(doc.get("device", "")),
                   meta=dict(doc.get("meta", {})))

    def merge(self, other: "TuningTable") -> "TuningTable":
        """Entries of ``other`` win on key conflicts."""
        self.entries.update(other.entries)
        return self


# ---------------------------------------------------------------------------
# Process-global registry (the seam dispatch/ops/serve/train consult).
# ---------------------------------------------------------------------------

_LOCK = threading.Lock()
_ACTIVE: Optional[TuningTable] = None


def set_active_table(
        table: Optional[Union[TuningTable, str, os.PathLike]]) -> None:
    """Install (or clear, with None) the process-global tuning table.

    Accepts a loaded :class:`TuningTable` or a path to a JSON table file.
    Install *before* tracing model code — jit caches keep whatever plans
    were active at trace time.
    """
    global _ACTIVE
    if table is not None and not isinstance(table, TuningTable):
        table = TuningTable.load(table)
    with _LOCK:
        _ACTIVE = table


def get_active_table() -> Optional[TuningTable]:
    return _ACTIVE


@contextlib.contextmanager
def use_table(table: Optional[Union[TuningTable, str, os.PathLike]]):
    """Scoped ``set_active_table`` (restores the previous table on exit)."""
    prev = get_active_table()
    set_active_table(table)
    try:
        yield get_active_table()
    finally:
        set_active_table(prev)
