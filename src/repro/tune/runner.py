"""Offline benchmark loop: compile + time every pruned candidate, check
correctness against :mod:`repro.kernels.ref`, record the winner.

Each candidate is executed through the same :func:`repro.kernels.ops.run_plan`
seam production uses (jit'd, plan as a static arg), so measured numbers are
the numbers dispatch will actually get.  Correctness is a *gate*, not a
tolerance: exact-int candidates must equal the int64 oracle bit-for-bit, and
fp32-combine Pallas candidates must equal the pure-jnp ref-kernel mirror
(identical padding + correction wrapper) bit-for-bit; only XLA fp32 digit
recursions — whose reference *is* the core algorithm being run — use a
normalized tolerance against the int64 oracle.

On this CPU container the Pallas kernels run in interpret mode
(``interpret=None`` auto-detects, same as the kernels themselves), so the
tuner is CI-runnable; on a real TPU the same sweep measures the MXU.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import ExecPlan, analytic_plan
from repro.kernels import ops
from repro.kernels.ref import ref_int_gemm_i64
from repro.tune import space as tune_space
from repro.tune.space import Shape


@dataclass
class Measurement:
    plan: ExecPlan
    us: float = float("inf")
    ok: bool = False
    error: str = ""
    bytes: float = 0.0     # compiled bytes-accessed (repro.obs.traffic)


@dataclass
class TuneResult:
    shape: Shape
    w: int
    backend: str
    winner: Optional[ExecPlan]
    winner_us: float
    default_us: float
    measurements: List[Measurement] = field(default_factory=list)

    @property
    def speedup_vs_default(self) -> float:
        if not self.winner or not np.isfinite(self.default_us) \
                or self.winner_us <= 0:
            return 1.0
        return self.default_us / self.winner_us


def make_operands(shape: Shape, w: int, seed: int = 0):
    """Random signed w-bit operands for an (M, K) x (K, N) problem."""
    m, k, n = shape
    rng = np.random.default_rng(seed)
    lim = 2 ** (w - 1)
    a = rng.integers(-lim, lim, size=(m, k)).astype(np.int32)
    b = rng.integers(-lim, lim, size=(k, n)).astype(np.int32)
    return jnp.asarray(a), jnp.asarray(b)


def check_plan(plan: ExecPlan, a, b, *,
               interpret: Optional[bool] = None) -> Tuple[bool, str]:
    """Bit-exact correctness gate for one candidate (see module docstring)."""
    try:
        out = np.asarray(ops.run_plan_jit(a, b, plan, interpret=interpret))
    except Exception as e:  # compile/shape failures count as candidate loss
        return False, f"execution failed: {type(e).__name__}: {e}"
    if plan.is_exact_int:
        ref = ref_int_gemm_i64(np.asarray(a), np.asarray(b))
        if not np.array_equal(out.astype(np.int64), ref):
            return False, "exact-int candidate != int64 oracle"
        return True, ""
    if plan.backend == "pallas":
        ref = np.asarray(ops.run_plan_jit(a, b, plan, interpret=interpret,
                                          use_ref_kernels=True))
        if not np.array_equal(out, ref):
            return False, "fp32 pallas candidate != ref-kernel mirror"
        return True, ""
    # XLA fp32 digit recursion: normalized tolerance vs the int64 oracle
    # (one fp32 rounding per output element by construction).
    ref = ref_int_gemm_i64(np.asarray(a), np.asarray(b)).astype(np.float64)
    denom = max(float(np.abs(ref).max()), 1.0)
    if float(np.abs(out - ref).max()) / denom > 1e-6:
        return False, "fp32 xla candidate exceeds normalized 1e-6 vs oracle"
    return True, ""


def bench_plan(plan: ExecPlan, a, b, *, iters: int = 3,
               interpret: Optional[bool] = None) -> float:
    """Steady-state microseconds per call (compile excluded)."""
    fn = lambda: ops.run_plan_jit(a, b, plan, interpret=interpret)
    fn().block_until_ready()                 # compile + warm
    t0 = time.perf_counter()
    for _ in range(max(iters, 1)):
        out = fn()
    out.block_until_ready()
    return (time.perf_counter() - t0) / max(iters, 1) * 1e6


def tune_shape(shape: Shape, w: int, *, m: int = 8, backend: str = "pallas",
               iters: int = 3, seed: int = 0,
               tile_choices: Optional[Sequence[int]] = None,
               strict_tpu: bool = False,
               interpret: Optional[bool] = None,
               max_candidates: Optional[int] = None,
               verbose: bool = False, context=None,
               record_bytes: bool = True) -> TuneResult:
    """Sweep the pruned space for one (shape, w, backend) problem.

    Returns the fastest *correct* candidate plus the measured time of the
    analytic default plan (so tables can report speedup honestly).
    ``max_candidates`` truncates the prior-ordered space — when it bites,
    the truncation is recorded in the result's measurement count, never
    silent (the CLI logs it).

    ``record_bytes`` (default on) records each correct candidate's compiled
    bytes-accessed (:func:`repro.obs.traffic.measure_plan_bytes`) alongside
    its wall time — the traffic column the roofline bench regresses on, and
    the honest tiebreaker when interpret-mode wall times are noise.

    ``context`` (an :class:`repro.core.context.ExecContext`) supplies the
    backend, and — when it carries a mesh with the pallas backend — rewrites
    ``shape`` to the per-shard LOCAL shape before sweeping: the shard-mapped
    kernel tiles its local block, so the local shape is both what the sweep
    must measure and the key ``select_plan`` will look up at serve time.
    """
    if context is not None:
        backend = context.backend
        if context.mesh is not None and backend == "pallas":
            shape = context.local_gemm_shape(shape)
    a, b = make_operands(shape, w, seed=seed)
    cands = tune_space.pruned_space(shape, w, m=m, backend=backend,
                                    tile_choices=tile_choices,
                                    strict_tpu=strict_tpu)
    if max_candidates is not None:
        cands = cands[:max_candidates]
    measurements: List[Measurement] = []
    winner: Optional[ExecPlan] = None
    winner_us = float("inf")
    for plan in cands:
        ok, err = check_plan(plan, a, b, interpret=interpret)
        if not ok:
            measurements.append(Measurement(plan, ok=False, error=err))
            continue
        us = bench_plan(plan, a, b, iters=iters, interpret=interpret)
        nbytes = 0.0
        if record_bytes:
            from repro.obs.traffic import measure_plan_bytes
            nbytes = measure_plan_bytes(plan, a, b, interpret=interpret)
        measurements.append(Measurement(plan, us=us, ok=True, bytes=nbytes))
        if us < winner_us:
            winner, winner_us = plan, us
        if verbose:
            print(f"    {plan.variant:7s} tiles={plan.tiles} "
                  f"int32={int(plan.combine_int32)} depth={plan.depth}: "
                  f"{us:9.1f} us  {nbytes / 1e6:8.2f} MB")

    # Time the analytic default (what production runs with no table) even
    # when its stock tiles are oversized for this shape — that is exactly
    # the waste the tuner exists to measure.
    default = analytic_plan(w, m, backend=backend)
    default_us = float("nan")
    try:
        default_us = bench_plan(default, a, b, iters=iters,
                                interpret=interpret)
    except Exception:
        pass                       # e.g. pallas depth>1: NotImplementedError
    return TuneResult(shape=shape, w=w, backend=backend, winner=winner,
                      winner_us=winner_us, default_us=default_us,
                      measurements=measurements)


def device_label() -> str:
    backend = jax.default_backend()
    if backend == "tpu":
        return f"tpu/{jax.devices()[0].device_kind}"
    return f"{backend}/interpret"
