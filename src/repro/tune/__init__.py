"""repro.tune — autotuning + kernel-variant registry for the integer GEMM
engine (search space, offline runner, persisted tuning tables).

See DESIGN.md §10.  Quickstart::

    PYTHONPATH=src python -m repro.tune --shapes smoke --out tuned/smoke.json
    # then install it process-wide:
    from repro.tune import set_active_table
    set_active_table("tuned/smoke.json")
"""
from repro.tune.space import (bucket_shape, candidates, cost_prior,
                              prior_plan, pruned_space, validate)
from repro.tune.table import (TuningTable, get_active_table, key_for,
                              set_active_table, use_table)
from repro.tune.runner import TuneResult, tune_shape

__all__ = [
    "TuneResult", "TuningTable", "bucket_shape", "candidates", "cost_prior",
    "get_active_table", "key_for", "prior_plan", "pruned_space",
    "set_active_table", "tune_shape", "use_table", "validate",
]
