"""Declarative autotuning search space for the integer-GEMM engine.

A point in the space is an :class:`repro.core.dispatch.ExecPlan`: kernel
variant (MM1 / KMM2 / MM2 / XLA-ref / FFIP), tile sizes (bm, bn, bk),
combine precision (int32 post-adder vs fp32) and digit-recursion depth.
``candidates`` enumerates the raw product space for one (M, K, N, w) problem;
``validate`` prunes it with the *provable* bounds — the ``max_exact_k``
int32-headroom bound from :mod:`repro.core.kmm`, the s8 digit-plane windows
from the paper's Fig. 10 dispatch rule, per-digit accumulator headroom, VMEM
footprint and tile sanity — and ``cost_prior`` ranks what survives with the
op-count model of :mod:`repro.core.complexity` (Eqs. 2-8), so the tuner
measures only plausible plans and table lookups fall back to a principled
analytic order when no measurement exists.

Pruning is a *correctness* filter, never a performance heuristic: every
candidate that survives ``validate`` must produce bit-exact results against
:mod:`repro.kernels.ref` (asserted by ``tests/test_tune.py`` across the whole
pruned space).
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Sequence, Tuple

from repro.core.complexity import (ADD, MULT, SHIFT, kmm_complexity,
                                   mm_complexity)
from repro.core.dispatch import (ExecPlan, VARIANTS, kmm_levels_needed,
                                 select_mode)
from repro.core.kmm import max_exact_k
from repro.core.strassen import (STRASSEN_VARIANTS, strassen_sub_plan,
                                 strassen_sub_shape)
from repro.kernels.fused_gemm import leaf_mag_bits

Shape = Tuple[int, int, int]   # (M, K, N)

TILE_CHOICES: Tuple[int, ...] = (32, 64, 128, 256)
# The FFIP literal materializes an (M, K/2, N) product tensor.
FFIP_MAX_ELEMS = 1 << 20
# Per-core VMEM budget for input tiles + int32 accumulators (bytes).
VMEM_BUDGET = 12 * 1024 * 1024
MAX_DEPTH = 3

_N_ACCUM = {"mm1": 1, "kmm2": 3, "mm2": 4, "fused": 3, "fused_mm2": 4}


def _n_accum(plan: ExecPlan) -> int:
    """int32 digit accumulators a plan's kernel keeps live (fused depth-2
    runs 9 leaf passes; the staged depth-2 path launches 3 KMM2 kernels of
    3 accumulators each — same count from the cost model's view)."""
    if plan.variant == "fused":
        return {0: 1, 1: 3, 2: 9}.get(plan.depth, 9)
    if plan.variant == "kmm2" and plan.depth == 2:
        return 9
    return _N_ACCUM.get(plan.variant, 1)


def _tile_ok(block: int, dim: int) -> bool:
    """A tile is sane if it is not more than one doubling past the dim
    (ops.py zero-pads up to the block multiple; bigger wastes whole tiles)."""
    return block <= 2 * max(dim, 1) or block == TILE_CHOICES[0]


def digit_accum_k_bound(w: int) -> int:
    """Largest (padded) K for which each digit-plane product accumulates
    exactly in int32 (kmm_gemm.py: digit magnitudes ~ 2**(w/2), so headroom
    covers K up to 2**(31 - w - 2))."""
    head = 31 - w - 2
    return 1 << head if head > 0 else 1


def plan_accum_k_bound(plan: ExecPlan) -> Optional[int]:
    """Per-digit int32 accumulator headroom of a *plan*: the largest padded
    K for which every digit-product accumulator stays exact.  None for the
    non-digit variants (mm1/xla_ref/ffip and the fused MM1 window), whose
    single int32 accumulator is bounded by ``max_exact_k`` instead.

    The bound tracks the largest magnitude entering an MXU pass
    (:func:`repro.kernels.fused_gemm.leaf_mag_bits`): KMM2's pre-adder
    reaches 2**h (the historical ``digit_accum_k_bound``); MM2 has no
    pre-adder so its digits stop at 2**(h-1) and K stretches further;
    depth-2 KMM's leaves are ~quarter-width, so K reaches
    2**(30 - 2*bits) (one guard bit under the int32 edge) — e.g. 2**20 at
    w=12 vs depth-1's 2**17, which is what makes depth 2 a *tuner
    alternative* on deep-K shapes inside the KMM2 window, not just the
    analytic default for w > 2m.
    """
    if plan.variant in ("mm1", "xla_ref", "ffip"):
        return None
    if plan.variant in STRASSEN_VARIANTS:
        # Composed full-problem bound (see strassen_k_bound): callers that
        # check ``padded K <= bound`` (_fused_plan_for, the shard-local
        # re-check) stay conservative — the sub-GEMMs pad the *half* K to
        # the tile multiple, so padding the full K overestimates.
        return strassen_k_bound(plan)
    if plan.variant == "fused" and plan.w <= plan.m:
        return None
    if plan.variant in ("mm2", "fused_mm2"):
        mode = "mm2"
    elif plan.depth == 2:
        mode = "kmm4"
    else:
        return digit_accum_k_bound(plan.w)
    head = 30 - 2 * leaf_mag_bits(mode, plan.w)
    return 1 << head if head > 0 else 1


def strassen_k_bound(plan: ExecPlan) -> int:
    """Largest full-problem K for which a strassen plan stays exact.

    Composed headroom derivation (DESIGN.md §16): the tile pre-adds
    (``A11 + A22`` etc.) produce (w+1)-bit sub-operands contracting over
    ``Ks = ceil(K / 2)``, so every sub-plan bound applies at ``w + 1`` on
    the half K:

      * the sub-product must fit int32 worst-case ->
        ``Ks <= max_exact_k(w + 1)``, i.e. ``K <= 2 * max_exact_k(w + 1)
        = 2**(30 - 2w)`` — the binding constraint (it is 2x tighter than
        the plain-product bound ``max_exact_k(w) = 2**(31 - 2w)``, the
        price of one bit of pre-add growth);
      * a Pallas sub-plan's per-digit accumulators must stay exact ->
        ``Ks <= plan_accum_k_bound(sub)`` (evaluated at w+1; the XLA digit
        recursion carries ring-exact int32 planes, so only the combine
        bound above binds there);
      * the recombined full output must fit int32 ->
        ``K <= max_exact_k(w)`` (4x looser than the first term, never
        binding — kept for the derivation's honesty).

    Conservative by the same unsigned worst-case convention as
    ``max_exact_k``; tests/test_strassen.py brute-forces the boundary at
    K-bound / K-bound+1.
    """
    sub = strassen_sub_plan(plan)
    bound = 2 * max_exact_k(sub.w)
    if sub.backend == "pallas":
        sub_accum = plan_accum_k_bound(sub)
        if sub_accum is not None:
            bound = min(bound, 2 * sub_accum)
    return min(bound, max_exact_k(plan.w))


def validate(plan: ExecPlan, shape: Shape, *,
             strict_tpu: bool = False) -> Optional[str]:
    """Return a rejection reason, or None if ``plan`` is valid for ``shape``.

    Everything here is a hard correctness/feasibility bound; rejected plans
    may crash, overflow int32, or silently produce wrong digits.
    """
    M, K, N = shape
    w, m = plan.w, plan.m
    if plan.variant not in VARIANTS:
        return f"unknown variant {plan.variant!r}"
    if m < 2:
        return f"m={m} < 2"
    if w < 1:
        return f"w={w} < 1"
    if plan.backend not in ("xla", "pallas"):
        return f"unknown backend {plan.backend!r}"

    if plan.variant == "xla_ref":
        # one fused int32 dot: the full 2w-bit products accumulate directly,
        # so the max_exact_k headroom bound is binding.
        if max_exact_k(w) < K:
            return f"xla_ref overflows int32: K={K} > max_exact_k={max_exact_k(w)}"
        if not plan.combine_int32:
            return "xla_ref is inherently exact; combine_int32 must be True"
        return None

    if plan.variant == "ffip":
        if K % 2:
            return "ffip needs even K"
        if M * (K // 2) * N > FFIP_MAX_ELEMS:
            return "ffip literal materializes (M, K/2, N); shape too large"
        # (a_e + b_o)(a_o + b_e) terms are (w+1)-bit x (w+1)-bit products.
        if max_exact_k(w + 1) < K:
            return f"ffip overflows int32 at K={K} for w={w}"
        if not plan.combine_int32:
            return "ffip is inherently exact; combine_int32 must be True"
        return None

    if plan.variant == "fused":
        # Single-pass kernel: in-kernel digit split + correction + epilogue
        # (kernels/fused_gemm.py).  Covers the MM1 window (w <= m, no
        # split), the single-level KMM2 window (m < w <= 2m - 2, depth 1)
        # and 4-digit depth-2 KMM (depth 2, any w whose depth-2 leaves fit
        # the multiplier: kmm_levels_needed(w, m) <= 2).
        if plan.backend != "pallas":
            return "fused kernel is pallas-only"
        if w <= m:
            if plan.depth != 0:
                return f"fused MM1 window is depth 0, got {plan.depth}"
            if not plan.combine_int32:
                return ("fused MM1-window core is inherently exact; "
                        "combine_int32 must be True")
            if max_exact_k(w) < K:
                return (f"fused mm1 overflows int32: K={K} > "
                        f"max_exact_k={max_exact_k(w)}")
        else:
            if plan.depth not in (1, 2):
                return ("fused KMM window implements depth 1 or 2, got "
                        f"{plan.depth}")
            if plan.depth == 1 and w > 2 * m - 2:
                return (f"fused kmm2 pre-adder digits exceed s8 for "
                        f"w={w} > {2*m - 2}")
            if plan.depth == 2:
                r_min = kmm_levels_needed(w, m)
                if r_min is None or r_min > 2:
                    return (f"depth-2 leaves exceed the m={m} multiplier "
                            f"at w={w}")
                if w < 4:
                    return f"depth 2 splits below 1-bit digits at w={w}"
            kp = -(-K // plan.block_k) * plan.block_k
            bound = plan_accum_k_bound(plan)
            if kp > bound:
                return (f"digit accumulators overflow int32: padded K={kp} > "
                        f"{bound}")
            if plan.combine_int32 and max_exact_k(w) < K:
                return (f"int32 combine fails headroom: K={K} > "
                        f"max_exact_k({w})={max_exact_k(w)}")
    elif plan.variant == "fused_mm2":
        # The fused kernel's 4-pass conventional boundary mode: no
        # pre-adder, so the digit planes fit the multiplier through
        # w <= 2m — covering the (2m-2, 2m] window KMM2 can't, and
        # doubling as a tuner alternative inside the KMM2 window (its
        # accumulator headroom is 4x deeper, see plan_accum_k_bound).
        if plan.backend != "pallas":
            return "fused_mm2 kernel is pallas-only"
        if plan.depth != 1:
            return f"fused_mm2 is single-level, got depth {plan.depth}"
        if w <= m:
            return f"fused_mm2 needs w > m ({w} <= {m})"
        if w > 2 * m:
            return (f"mm2 digit planes exceed the multiplier for "
                    f"w={w} > {2*m}")
        kp = -(-K // plan.block_k) * plan.block_k
        bound = plan_accum_k_bound(plan)
        if kp > bound:
            return (f"digit accumulators overflow int32: padded K={kp} > "
                    f"{bound}")
        if plan.combine_int32 and max_exact_k(w) < K:
            return (f"int32 combine fails headroom: K={K} > "
                    f"max_exact_k({w})={max_exact_k(w)}")
    elif plan.variant == "mm1":
        if w > m:
            return f"mm1 needs w <= m ({w} > {m})"
        if plan.backend == "xla":
            return "mm1 on xla is the xla_ref variant"
        if not plan.combine_int32:
            return "mm1 is inherently exact; combine_int32 must be True"
        # single int8xint8 -> int32 accumulation over K: same headroom
        # bound as the fused dot.
        if max_exact_k(w) < K:
            return (f"mm1 overflows int32: K={K} > "
                    f"max_exact_k={max_exact_k(w)}")
    elif plan.variant in STRASSEN_VARIANTS:
        # One tile-level Strassen split (core/strassen.py): 7 sub-GEMMs on
        # the even-padded (M/2, K/2, N/2) quadrants with (w+1)-bit
        # pre-added operands.  Every sub-plan bound — mode windows, digit
        # accumulators, tile sanity and VMEM on the *half* dims — is
        # checked by recursing into the derived sub-plan; the explicit
        # headroom check below is the composed-bound statement callers can
        # reason about (strassen_k_bound).
        if plan.depth != 1:
            return f"strassen is one tile-split level, got depth {plan.depth}"
        if not plan.combine_int32:
            return ("strassen combines are int32 ring arithmetic; "
                    "combine_int32 must be True")
        if plan.variant == "strassen+kmm2" and plan.backend != "pallas":
            return "strassen+kmm2 runs fused pallas sub-GEMMs; pallas only"
        bound = strassen_k_bound(plan)
        if K > bound:
            return (f"strassen sub-products overflow int32: K={K} > "
                    f"composed bound {bound} (= 2*max_exact_k({w + 1}) "
                    f"after the one-bit pre-add growth)")
        sub = strassen_sub_plan(plan)
        sub_reason = validate(sub, strassen_sub_shape(shape),
                              strict_tpu=strict_tpu)
        if sub_reason is not None:
            return f"strassen sub-GEMM (w={sub.w}) invalid: {sub_reason}"
        return None
    else:  # kmm2 / mm2 digit variants
        if w < 2:
            return "digit split needs w >= 2"
        if plan.depth < 1 or plan.depth > MAX_DEPTH:
            return f"depth {plan.depth} outside [1, {MAX_DEPTH}]"
        if 2 ** plan.depth > w:
            return f"depth {plan.depth} splits below 1-bit digits at w={w}"
        if plan.backend == "pallas":
            if plan.variant == "mm2" and plan.depth != 1:
                return "pallas mm2 is single-level"
            if plan.variant == "kmm2" and plan.depth not in (1, 2):
                return "pallas kmm2 implements depth 1 or 2"
            if plan.variant == "kmm2" and plan.depth == 1 \
                    and w > 2 * m - 2:
                # the paper's Fig. 10 window: As = A1 + A0 must fit m bits
                return f"kmm2 pre-adder digits exceed s8 for w={w} > {2*m - 2}"
            if plan.variant == "kmm2" and plan.depth == 2:
                r_min = kmm_levels_needed(w, m)
                if r_min is None or r_min > 2:
                    return (f"depth-2 leaves exceed the m={m} multiplier "
                            f"at w={w}")
            if plan.variant == "mm2" and w > 2 * m:
                return f"mm2 digit planes exceed s8 for w={w} > {2*m}"
            kp = -(-K // plan.block_k) * plan.block_k
            bound = plan_accum_k_bound(plan)
            if kp > bound:
                return (f"digit accumulators overflow int32: padded K={kp} > "
                        f"{bound}")
        else:
            # XLA digit recursion: every leaf digit must fit the multiplier.
            r_min = kmm_levels_needed(w, m)
            if r_min is None:
                return f"w={w} too wide for m={m}"
            if plan.depth < max(r_min, 1):
                return f"depth {plan.depth} leaves digits wider than m={m}"
        if plan.combine_int32 and max_exact_k(w) < K:
            return (f"int32 combine fails headroom: K={K} > "
                    f"max_exact_k({w})={max_exact_k(w)}")

    # Tile sanity + VMEM footprint (pallas only; XLA ignores tiles).
    if plan.backend == "pallas":
        for b, d, name in ((plan.block_m, M, "block_m"),
                           (plan.block_n, N, "block_n"),
                           (plan.block_k, K, "block_k")):
            if b < 8 or b & (b - 1):
                return f"{name}={b} must be a power of two >= 8"
            if not _tile_ok(b, d):
                return f"{name}={b} oversized for dim {d}"
        if strict_tpu:
            if plan.block_n % 128:
                return f"TPU lane dim: block_n={plan.block_n} % 128 != 0"
            if plan.block_m % 32:
                return f"TPU s8 sublane: block_m={plan.block_m} % 32 != 0"
        vmem = vmem_footprint(plan)
        if vmem > VMEM_BUDGET:
            return f"VMEM footprint {vmem} > {VMEM_BUDGET}"
    return None


def vmem_footprint(plan: ExecPlan) -> int:
    """Per-grid-step VMEM bytes of a pallas plan (0 for XLA plans).

    The same accounting serves two gates: candidate pruning here, and the
    per-shard capability negotiation in :mod:`repro.dist.shard_gemm` —
    under a mesh each shard launches the kernel on its *local* block, so
    the footprint of the (possibly table-chosen) tiles must fit one core's
    VMEM regardless of how many shards the global GEMM spans.
    """
    if plan.variant in STRASSEN_VARIANTS:
        # What launches is the sub-kernel: 7 sequential sub-GEMMs, each
        # with the sub-plan's own per-grid-step footprint (0 for the
        # XLA-sub variant).
        return vmem_footprint(strassen_sub_plan(plan))
    if plan.backend != "pallas":
        return 0
    n_acc = _n_accum(plan)
    if plan.variant in ("fused", "fused_mm2"):
        # Raw-operand tiles (narrowest carrier: int8 in the MM1 window,
        # int16 through w = 16, int32 beyond), the mode's digit
        # accumulators (1 / 3 / 4 / 9), plus the zero-point rowsum/colsum
        # scratch and the dequant-epilogue scale tiles.
        opd = (1 if plan.variant == "fused" and plan.w <= plan.m else
               2 if plan.w <= 16 else 4)
        return (opd * (plan.block_m * plan.block_k
                       + plan.block_k * plan.block_n)
                + (n_acc + 1) * plan.block_m * plan.block_n * 4
                + 4 * 2 * (plan.block_m + plan.block_n))
    # Staged plane kernels launch one level at a time: depth-2 kmm2 runs
    # three single-level launches on int16 planes, so its *per-launch*
    # footprint is the single-level kernel's with 2-byte planes.
    plane_bytes = 2 if (plan.variant == "kmm2" and plan.depth == 2) else 1
    n_acc = min(n_acc, 4)
    planes = 1 if plan.variant == "mm1" else 2
    return (planes * plane_bytes * (plan.block_m * plan.block_k
                                    + plan.block_k * plan.block_n)
            + (n_acc + 1) * plan.block_m * plan.block_n * 4)    # acc+out


def candidates(shape: Shape, w: int, *, m: int = 8, backend: str = "pallas",
               tile_choices: Optional[Sequence[int]] = None,
               strict_tpu: bool = False) -> Iterator[ExecPlan]:
    """Enumerate the *valid* candidates for one GEMM problem.

    ``backend`` selects the execution substrate of the digit variants; the
    backend-independent reference variants (xla_ref, ffip) are always
    offered so the tuner can discover when a plain fused dot wins (small K
    within headroom).
    """
    tiles = tuple(tile_choices) if tile_choices else TILE_CHOICES
    M, K, N = shape

    def emit(plan: ExecPlan) -> Iterator[ExecPlan]:
        if validate(plan, shape, strict_tpu=strict_tpu) is None:
            yield plan

    yield from emit(ExecPlan("xla_ref", w, m, backend=backend,
                             combine_int32=True, depth=0, source="space"))
    yield from emit(ExecPlan("ffip", w, m, backend=backend,
                             combine_int32=True, depth=0, source="space"))
    # Tile-level Strassen with XLA sub-GEMMs: like xla_ref/ffip it is
    # backend-independent (no tiles of its own), so it is offered on both
    # sweep backends.  The fused-sub composition is tile-parameterized and
    # emitted inside the pallas tile loop below.
    yield from emit(ExecPlan("strassen", w, m, backend=backend,
                             combine_int32=True, depth=1, source="space"))

    if backend == "xla":
        r_min = kmm_levels_needed(w, m) or 1
        for variant in ("kmm2", "mm2"):
            for depth in range(max(r_min, 1), MAX_DEPTH + 1):
                for ci in (False, True):
                    yield from emit(ExecPlan(
                        variant, w, m, backend="xla", combine_int32=ci,
                        depth=depth, source="space"))
        return

    for bm in tiles:
        for bn in tiles:
            for bk in tiles:
                yield from emit(ExecPlan(
                    "mm1", w, m, backend="pallas", block_m=bm, block_n=bn,
                    block_k=bk, combine_int32=True, depth=0, source="space"))
                for depth in ((0,) if w <= m else (1, 2)):
                    for ci in ((True,) if w <= m else (False, True)):
                        yield from emit(ExecPlan(
                            "fused", w, m, backend="pallas", block_m=bm,
                            block_n=bn, block_k=bk, combine_int32=ci,
                            depth=depth, source="space"))
                for ci in (False, True):
                    yield from emit(ExecPlan(
                        "fused_mm2", w, m, backend="pallas", block_m=bm,
                        block_n=bn, block_k=bk, combine_int32=ci,
                        depth=1, source="space"))
                for variant, depth in (("kmm2", 1), ("kmm2", 2),
                                       ("mm2", 1)):
                    for ci in (False, True):
                        yield from emit(ExecPlan(
                            variant, w, m, backend="pallas", block_m=bm,
                            block_n=bn, block_k=bk, combine_int32=ci,
                            depth=depth, source="space"))
                # Strassen over fused-Pallas sub-GEMMs: the tiles are the
                # *sub-kernel's* tiles (validated against the half dims by
                # the recursive sub-validate), so they ride the same sweep
                # axes as every other pallas candidate.
                yield from emit(ExecPlan(
                    "strassen+kmm2", w, m, backend="pallas", block_m=bm,
                    block_n=bn, block_k=bk, combine_int32=True,
                    depth=1, source="space"))


def cost_prior(plan: ExecPlan, shape: Shape) -> float:
    """Analytic cost of a plan, in weighted op units.

    Built from the paper's complexity recursions (:mod:`repro.core.complexity`
    Eqs. 2/5 evaluated at d=1 give per-product op counts: 3**r multiplies per
    product for KMM, 4**r for MM, plus the per-output combine adds/shifts),
    scaled to the padded rectangular problem, plus a per-tile overhead term
    so the prior prefers fewer, larger grid steps when VMEM allows.
    """
    M, K, N = shape
    if plan.variant in STRASSEN_VARIANTS:
        # 7 sub-GEMMs on the half problem, plus the tile-add plane traffic
        # Strassen adds on top of the digit-plane traffic the sub-prior
        # already charges: the 10 operand pre-adds read/write int32
        # (M/2, K/2) and (K/2, N/2) planes (charged at the staged
        # plane-pass weight of 3 units per element-pass, 5 passes per
        # operand plane), and the 8-term output combine reads 7 product
        # quadrants and writes 4 (23 element-passes of M/2 x N/2).
        # Without this term the prior-only fallback would blindly prefer
        # strassen on small shapes where the adds dominate the saved
        # eighth of multiply work (ISSUE 10 satellite).
        sub = strassen_sub_plan(plan)
        Ms, Ks, Ns = strassen_sub_shape(shape)
        adds = 15.0 * (Ms * Ks + Ks * Ns) + 23.0 * Ms * Ns
        return 7.0 * cost_prior(sub, (Ms, Ks, Ns)) + adds + 7 * 4096.0
    bm, bn, bk = plan.tiles
    if plan.backend == "pallas":
        Mp, Np, Kp = (-(-M // bm) * bm, -(-N // bn) * bn, -(-K // bk) * bk)
        grid = (Mp // bm) * (Np // bn) * (Kp // bk)
    else:
        Mp, Np, Kp = M, N, K
        grid = 1

    if plan.variant == "xla_ref":
        mults, combine = float(Mp * Np * Kp), 0.0
    elif plan.variant == "ffip":
        mults = float(M * N * (K // 2) + (M + N) * (K // 2))
        combine = float(M * N)
    else:
        n = max(plan.digits, 1)
        if plan.variant == "mm1" or n == 1:
            mults, combine = float(Mp * Np * Kp), 0.0
        else:
            fn = kmm_complexity if plan.variant in ("kmm2", "fused") \
                else mm_complexity
            ops = fn(n, plan.w, 1)            # d=1: per-product / per-output
            mults = ops.total_of(MULT) * Mp * Np * Kp
            combine = (ops.total_of(ADD) + ops.total_of(SHIFT)) * Mp * Np
    # fp32 combine costs one extra cast/round per accumulator per output.
    if not plan.combine_int32 \
            and plan.variant in ("kmm2", "mm2", "fused", "fused_mm2"):
        combine += _n_accum(plan) * Mp * Np
    # Memory-traffic asymmetry of the Pallas digit paths: the staged kernels
    # materialize the digit-plane arrays in HBM (twice as many at depth 2)
    # and rebuild the zero-point sums in two more passes; the fused kernel
    # splits in-register but recomputes each operand tile's split once per
    # reuse across the other grid axis.
    if plan.backend == "pallas" and plan.variant in ("kmm2", "mm2"):
        combine += 3.0 * (plan.digits // 2) * (Mp * Kp + Kp * Np)
    elif plan.variant in ("fused", "fused_mm2") and plan.w > plan.m:
        combine += 0.5 * (Mp * Kp * (Np // bn) + Kp * Np * (Mp // bm))
    return mults + combine + 512.0 * grid


def pruned_space(shape: Shape, w: int, *, m: int = 8,
                 backend: str = "pallas",
                 tile_choices: Optional[Sequence[int]] = None,
                 strict_tpu: bool = False) -> List[ExecPlan]:
    """The valid candidates for ``shape``/``w``, best-prior first."""
    cands = list(candidates(shape, w, m=m, backend=backend,
                            tile_choices=tile_choices, strict_tpu=strict_tpu))
    return sorted(cands, key=lambda p: cost_prior(p, shape))


def prior_plan(shape: Shape, w: int, *, m: int = 8, backend: str = "xla",
               exact: bool = False) -> Optional[ExecPlan]:
    """Best candidate by the cost prior alone (no measurement) — the table
    fallback when a key has never been swept.  Restricted to candidates in
    the analytic plan's numerics class so un-tuned keys stay bit-identical
    to the paper's rule (see dispatch.select_plan)."""
    import dataclasses

    from repro.core.dispatch import analytic_plan, numerics_fingerprint
    want = numerics_fingerprint(analytic_plan(w, m, backend=backend,
                                              exact=exact))
    best, best_cost = None, None
    for cand in candidates(shape, w, m=m, backend=backend):
        if numerics_fingerprint(cand) != want:
            continue
        c = cost_prior(cand, shape)
        if best_cost is None or c < best_cost:
            best, best_cost = cand, c
    if best is not None:
        best = dataclasses.replace(best, source="prior")
    return best


def _round_pow2(x: int, lo: int = 8) -> int:
    v = lo
    while v < x:
        v *= 2
    return v


def bucket_shape(shape: Shape) -> Shape:
    """Power-of-two M/N/K buckets used as table keys (min bucket 8)."""
    return tuple(_round_pow2(int(d)) for d in shape)  # type: ignore


def local_shape(shape: Shape, mesh) -> Shape:
    """Per-shard (M, K, N) of a GEMM under ``mesh``'s canonical sharded
    layout (M over data axes, N over model, K replicated — see
    :mod:`repro.dist.shard_gemm`).  Identity when the mesh can't tile the
    GEMM (the XLA fallback runs on the global shape anyway).

    This is the shape tables are keyed on (and bounds validated against)
    under a mesh: the shard-mapped kernel tiles its local block, so local
    M/N drive tile sanity and the VMEM footprint, and the local K drives
    the ``max_exact_k`` / digit-accumulator headroom bounds.
    """
    from repro.dist.shard_gemm import negotiate, local_shape as _local
    spec, _ = negotiate(shape, mesh)
    if spec is None:
        return shape
    return _local(shape, spec, mesh)
