"""``python -m repro.tune`` — sweep GEMM shapes, persist winner tables.

Shape sources:

  * ``--shapes smoke``    two tiny shapes (CI tune-smoke job)
  * ``--shapes configs``  the GEMM (K, N) pairs of every registered arch in
                          ``repro.configs`` x the M values of the assignment
                          shape cells (decode batches + prefill buckets),
                          capped by ``--max-dim`` so the sweep is feasible on
                          CPU interpret mode (table keys bucket anyway)
  * ``--shapes serve``    the serve engine's prefill-bucket ladder x the
                          model dims of ``--arch``
  * ``--shapes MxKxN``    explicit problems, repeatable

Example:

    PYTHONPATH=src python -m repro.tune --shapes configs \
        --w 8 12 --backend pallas --out tuned/default.json
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Set, Tuple

from repro.tune import runner, space
from repro.tune.table import DEFAULT_PATH, TuningTable

Shape = Tuple[int, int, int]

SMOKE_SHAPES: Tuple[Shape, ...] = ((64, 64, 64), (64, 128, 64))


def _cap_bucket(d: int, cap: int) -> int:
    return space.bucket_shape((min(d, cap),) * 3)[0]


def _config_shapes(cap: int) -> List[Shape]:
    """GEMM shapes the registered archs actually run (bucketed, capped)."""
    from repro.configs import SHAPES as CELLS, get_config, list_archs
    from repro.serve.engine import prompt_buckets_for

    ms: Set[int] = {cell.global_batch for cell in CELLS.values()
                    if cell.kind == "decode"}
    ms |= set(prompt_buckets_for(512))           # serve prefill ladder
    ms |= {cell.global_batch * min(cell.seq_len, 16)
           for cell in CELLS.values() if cell.kind == "train"}
    out: Set[Shape] = set()
    for arch in list_archs():
        cfg = get_config(arch)
        kns = {(cfg.d_model, cfg.q_dim), (cfg.d_model, cfg.kv_dim),
               (cfg.q_dim, cfg.d_model), (cfg.d_model, cfg.d_ff),
               (cfg.d_ff, cfg.d_model), (cfg.d_model, cfg.padded_vocab)}
        if cfg.n_experts:
            fe = cfg.d_ff_expert or cfg.d_ff
            kns |= {(cfg.d_model, fe), (fe, cfg.d_model)}
        for m in ms:
            for k, n in kns:
                out.add((_cap_bucket(m, cap), _cap_bucket(k, cap),
                         _cap_bucket(n, cap)))
    return sorted(out)


def _serve_shapes(arch: str, max_seq: int, cap: int, smoke: bool) -> List[Shape]:
    from repro.configs import get_config
    from repro.serve.engine import prompt_buckets_for

    cfg = get_config(arch, smoke=smoke)
    out: Set[Shape] = set()
    for m in prompt_buckets_for(max_seq):
        for k, n in ((cfg.d_model, cfg.q_dim), (cfg.d_model, cfg.d_ff),
                     (cfg.d_ff, cfg.d_model)):
            out.add((_cap_bucket(m, cap), _cap_bucket(k, cap),
                     _cap_bucket(n, cap)))
    return sorted(out)


def _parse_shapes(args) -> List[Shape]:
    shapes: List[Shape] = []
    for tok in args.shapes:
        if tok == "smoke":
            shapes.extend(SMOKE_SHAPES)
        elif tok == "configs":
            shapes.extend(_config_shapes(args.max_dim))
        elif tok == "serve":
            shapes.extend(_serve_shapes(args.arch, args.max_seq,
                                        args.max_dim, args.smoke_config))
        else:
            try:
                m, k, n = (int(x) for x in tok.lower().split("x"))
            except ValueError:
                raise SystemExit(
                    f"bad --shapes token {tok!r}: expected "
                    f"smoke|configs|serve|MxKxN")
            shapes.append((m, k, n))
    return sorted(set(shapes))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Autotune integer-GEMM kernel variants and tiles; "
                    "persist winner tables under tuned/.")
    ap.add_argument("--shapes", nargs="+", default=["configs"],
                    help="smoke | configs | serve | explicit MxKxN ...")
    ap.add_argument("--w", nargs="+", type=int, default=[8, 12],
                    help="bitwidths to sweep (default: the policy widths)")
    ap.add_argument("--m", type=int, default=8, help="multiplier bitwidth")
    ap.add_argument("--backend", nargs="+", default=["pallas"],
                    choices=["pallas", "xla"])
    ap.add_argument("--out", default=DEFAULT_PATH,
                    help=f"output table path (default {DEFAULT_PATH}); "
                         f"merged into if it already exists")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tiles", nargs="+", type=int, default=None,
                    help="restrict tile choices (default "
                         f"{space.TILE_CHOICES})")
    ap.add_argument("--max-candidates", type=int, default=None,
                    help="truncate the prior-ordered space per shape")
    ap.add_argument("--max-dim", type=int, default=1024,
                    help="cap derived config/serve dims (CPU feasibility)")
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="arch for --shapes serve")
    ap.add_argument("--max-seq", type=int, default=512,
                    help="serve bucket ladder upper bound")
    ap.add_argument("--smoke-config", action="store_true",
                    help="use the smoke-scale config for --shapes serve")
    ap.add_argument("--strict-tpu", action="store_true",
                    help="prune tiles that violate real-TPU tiling "
                         "(lane 128 / s8 sublane 32)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    shapes = _parse_shapes(args)
    if not shapes:
        raise SystemExit("no shapes to sweep")

    try:
        table = TuningTable.load(args.out)
        print(f"merging into existing table {args.out} "
              f"({len(table)} entries)")
    except FileNotFoundError:
        table = TuningTable()
    table.device = runner.device_label()

    n_jobs = len(shapes) * len(args.w) * len(args.backend)
    print(f"sweeping {len(shapes)} shapes x w={args.w} x "
          f"backends={args.backend} ({n_jobs} problems) on {table.device}")
    t0 = time.time()
    done = 0
    for backend in args.backend:
        for w in args.w:
            for shape in shapes:
                done += 1
                res = runner.tune_shape(
                    shape, w, m=args.m, backend=backend, iters=args.iters,
                    seed=args.seed, tile_choices=args.tiles,
                    strict_tpu=args.strict_tpu,
                    max_candidates=args.max_candidates,
                    verbose=args.verbose)
                n_ok = sum(1 for r in res.measurements if r.ok)
                n_bad = sum(1 for r in res.measurements if not r.ok)
                if res.winner is None:
                    print(f"[{done}/{n_jobs}] {backend} w={w} "
                          f"{shape}: NO correct candidate "
                          f"({n_bad} rejected) — key skipped")
                    continue
                key = table.put(
                    backend, shape, w, res.winner,
                    us=round(res.winner_us, 2),
                    us_default=(round(res.default_us, 2)
                                if res.default_us == res.default_us else None),
                    n_candidates=len(res.measurements))
                print(f"[{done}/{n_jobs}] {key}: {res.winner.variant} "
                      f"tiles={res.winner.tiles} "
                      f"int32={int(res.winner.combine_int32)} "
                      f"{res.winner_us:.1f}us "
                      f"(x{res.speedup_vs_default:.2f} vs default, "
                      f"{n_ok} ok / {n_bad} pruned-at-run)")
    table.meta["sweep_s"] = f"{time.time() - t0:.1f}"
    table.save(args.out)
    print(f"wrote {args.out}: {len(table)} entries "
          f"({time.time() - t0:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
