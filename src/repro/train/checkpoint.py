"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic, auto-resume.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a temp dir
and ``os.replace``d into place, so a crash mid-save never corrupts the latest
checkpoint.  Arrays are saved in logical (unsharded) layout keyed by pytree
path, so a restart may use a different mesh shape (elastic scaling): loading
device_puts each array with the *new* mesh's shardings.

``AsyncCheckpointer`` runs the serialization on a worker thread; ``wait()``
joins before the next save or on shutdown (at most one in flight — matching
typical async-checkpoint semantics).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any

_SEP = "||"


def _flatten(tree: Params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                        for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(tree_like: Params, flat: Dict[str, np.ndarray]) -> Params:
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in paths_leaves:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                        for k in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"checkpoint shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, tree: Params,
         meta: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_save_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": int(step), "time": time.time(),
                    "n_arrays": len(flat), **(meta or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load(ckpt_dir: str, tree_like: Params, step: Optional[int] = None
         ) -> Tuple[int, Params, Dict[str, Any]]:
    """Restore into the structure (and shardings) of ``tree_like``."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as npz:
        flat = {k: npz[k] for k in npz.files}
    restored = _unflatten(tree_like, flat)

    def put(like, arr):
        if hasattr(like, "sharding"):
            return jax.device_put(arr.astype(like.dtype), like.sharding)
        return arr
    restored = jax.tree.map(put, tree_like, restored)
    return step, restored, manifest


def prune(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (latest_step(ckpt_dir),) if s is not None)
    names = sorted(n for n in os.listdir(ckpt_dir)
                   if re.fullmatch(r"step_\d+", n))
    for name in names[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


class AsyncCheckpointer:
    """One-in-flight async saver (serialize on a worker thread)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Params,
             meta: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, meta)
                prune(self.ckpt_dir, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
