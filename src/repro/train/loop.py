"""Training loop with fault tolerance: auto-resume, async checkpoints,
deterministic skip-ahead data, and a step-time straggler watchdog.

The loop is mesh-agnostic: on restart the mesh may change shape (elastic
scaling) because checkpoints store logical arrays (see train/checkpoint.py);
`run_training` re-sharding-constrains everything it loads.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import DataConfig, DataIterator
from repro.dist import sharding as shard
from repro.launch import steps as steps_mod
from repro.models import lm
from repro.models.config import ModelConfig
from repro.train import checkpoint as ckpt
from repro.train import optim

log = logging.getLogger("repro.train")


@dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    seed: int = 0
    straggler_factor: float = 3.0   # watchdog: step > factor x median -> warn
    optimizer: optim.AdamWConfig = field(default_factory=optim.AdamWConfig)
    # Execution context (repro.core.context.ExecContext); its tuning table
    # is installed into the process-global registry before the train step
    # traces (no table leaves the currently active one untouched; clear
    # with repro.tune.set_active_table(None)).  Numerics-pinned: changes
    # how quantized GEMMs run, never the loss values.
    context: Optional[Any] = None
    # Deprecated: table path — use context=ExecContext(tuning_table=...).
    tuning_table: Optional[str] = None


@dataclass
class TrainResult:
    final_step: int
    losses: Dict[int, float]
    restored_from: Optional[int]
    straggler_events: int


def _shard_batch(batch: Dict[str, np.ndarray], mesh: Mesh):
    spec = shard.batch_spec(mesh)
    bspec = spec[0] if len(spec) else None

    def put(x):
        ndim = x.ndim
        return jax.device_put(
            x, NamedSharding(mesh, P(*( [bspec] + [None] * (ndim - 1) ))))

    return {k: put(v) for k, v in batch.items()}


def run_training(cfg: ModelConfig, mesh: Mesh, tc: TrainConfig,
                 data_cfg: Optional[DataConfig] = None,
                 hooks: Optional[Dict[str, Callable]] = None) -> TrainResult:
    hooks = hooks or {}
    from repro.core.context import resolve_context
    ctx = resolve_context(tc.context, what="TrainConfig",
                          tuning_table=tc.tuning_table or None)
    if ctx.tuning_table is not None:
        from repro.tune import set_active_table
        set_active_table(ctx.tuning_table)
    data_cfg = data_cfg or DataConfig(
        vocab_size=cfg.vocab_size, seq_len=256, global_batch=8,
        frontend=cfg.frontend, frontend_dim=cfg.frontend_dim,
        frontend_tokens=cfg.frontend_tokens, encdec=cfg.is_encdec,
        seed=tc.seed)

    with mesh:
        params_abs = steps_mod.abstract_params(cfg, mesh)
        param_sh = jax.tree.map(lambda a: a.sharding, params_abs)
        key = jax.random.PRNGKey(tc.seed)
        params = jax.jit(
            lambda k: lm.init_params(k, cfg), out_shardings=param_sh)(key)
        opt_state = optim.init(params)

        restored_from = None
        if tc.ckpt_dir:
            last = ckpt.latest_step(tc.ckpt_dir)
            if last is not None:
                _, (params, opt_state), _ = ckpt.load(
                    tc.ckpt_dir, (params, opt_state), step=last)
                restored_from = last
                log.info("resumed from step %d", last)

        start_step = int(jax.device_get(opt_state.step))
        train_step = jax.jit(
            steps_mod.make_train_step(cfg, tc.optimizer),
            donate_argnums=(0, 1))

        it = DataIterator(data_cfg, start_step=start_step)  # skip-ahead
        saver = ckpt.AsyncCheckpointer(tc.ckpt_dir, keep=tc.ckpt_keep) \
            if tc.ckpt_dir else None

        losses: Dict[int, float] = {}
        step_times = []
        straggler_events = 0
        for step in range(start_step, tc.steps):
            batch = _shard_batch(next(it), mesh)
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            if "inject_fault" in hooks:
                hooks["inject_fault"](step)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.time() - t0
            step_times.append(dt)
            if len(step_times) > 5:
                median = float(np.median(step_times[-50:]))
                if dt > tc.straggler_factor * median:
                    straggler_events += 1
                    log.warning("straggler: step %d took %.3fs (median %.3fs)",
                                step, dt, median)
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
            if step % tc.log_every == 0 or step == tc.steps - 1:
                losses[step] = loss
                log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
            if saver and (step + 1) % tc.ckpt_every == 0:
                saver.save(step + 1, (params, opt_state),
                           meta={"arch": cfg.name})
        if saver:
            saver.save(tc.steps, (params, opt_state), meta={"arch": cfg.name})
            saver.wait()
    return TrainResult(tc.steps, losses, restored_from, straggler_events)
