"""AdamW optimizer (from scratch, pure JAX pytree ops) + LR schedules.

State is kept in fp32 regardless of param dtype; the sharding of every state
leaf follows its parameter (2D TP x FSDP sharding -> optimizer state is
sharded /256 on the production mesh with no extra ZeRO machinery).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def init(params: Params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads: Params, state: OptState, params: Params
           ) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        upd = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * upd
        return new_p.astype(p.dtype), mu, nu

    flat = jax.tree.map(leaf, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics
