"""Pallas TPU kernel: KMM2 integer GEMM (paper Fig. 8 adapted to the MXU).

The fixed-precision KMM architecture keeps three sub-MXUs, one per digit-plane
product (C1, Cs, C0), each with its own accumulator, and combines them once in
the post-adder unit (Fig. 9).  The TPU-native mapping:

  * the three "sub-MXUs" are three int8 MXU passes per (bm, bk)x(bk, bn) tile;
  * the three dedicated accumulators are three int32 VMEM scratch buffers that
    persist across the K grid dimension — each digit product accumulates
    *exactly* in int32 (digit magnitudes are ~2^(w/2), so the int32 headroom
    covers K up to 2^(31 - w - 2));
  * the post-adder combine runs once per output tile on the final K step,
    either in int32 (exact when 2w + log2(K) + 2 <= 31) or in fp32 (the
    paper's wide 2w + w_a accumulators have no int32 TPU analogue — see
    DESIGN.md §2); every input to the combine is an exact int32, so fp32
    introduces a single rounding per output element;
  * Algorithm 5 appears structurally: the MXU dot over block_k is the narrow
    pre-accumulation (p = block_k) and each digit accumulator sees exactly one
    add per K tile — the wide-add count drops by block_k, as in Fig. 6;
  * the A_s/B_s pre-adders (X-adder vector of Fig. 8) are int8 VPU adds on the
    digit planes inside the kernel.

Digit convention (signed, MXU s8-native): the wrapper in ops.py splits w-bit
operands at h = ceil(w/2) into a signed high digit and a *zero-centered* low
digit (low - 2^(h-1)), then folds the centering back with the paper's
zero-point-adjuster correction (Section IV-D).  With centered digits the
A_s = A1 + A0 plane fits s8 for every w <= 2m - 2 = 14 — the same bound that
defines the paper's KMM2 dispatch window.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

Array = jax.Array


def _kmm2_kernel(a1_ref, a0_ref, b1_ref, b0_ref, out_ref,
                 acc1_ref, accs_ref, acc0_ref, *, h: int, nk: int,
                 combine_int32: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        accs_ref[...] = jnp.zeros_like(accs_ref)
        acc0_ref[...] = jnp.zeros_like(acc0_ref)

    a1 = a1_ref[...]
    a0 = a0_ref[...]
    b1 = b1_ref[...]
    b0 = b0_ref[...]
    # Fig. 8 input pre-adders: A_s = A1 + A0, B_s = B1 + B0 (s8-safe, w<=14).
    a_s = a1 + a0
    b_s = b1 + b0
    # Three sub-MXU passes; int32 MXU accumulation is the Algorithm-5 pre-sum.
    acc1_ref[...] += jnp.dot(a1, b1, preferred_element_type=jnp.int32)
    accs_ref[...] += jnp.dot(a_s, b_s, preferred_element_type=jnp.int32)
    acc0_ref[...] += jnp.dot(a0, b0, preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _combine():
        # KMM post-adder unit (Fig. 9): C = C1<<2h + (Cs-C1-C0)<<h + C0.
        c1 = acc1_ref[...]
        cs = accs_ref[...]
        c0 = acc0_ref[...]
        if combine_int32:
            mid = cs - c1 - c0
            out_ref[...] = (c1 << (2 * h)) + (mid << h) + c0
        else:
            c1f = c1.astype(jnp.float32)
            c0f = c0.astype(jnp.float32)
            mid = cs.astype(jnp.float32) - c1f - c0f
            out_ref[...] = c1f * (2.0 ** (2 * h)) + mid * (2.0 ** h) + c0f


@functools.partial(
    jax.jit,
    static_argnames=("h", "block_m", "block_n", "block_k", "combine_int32",
                     "interpret"),
)
def kmm2_gemm_planes(
    a1: Array, a0: Array, b1: Array, b0: Array, *,
    h: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    combine_int32: bool = False,
    interpret: Optional[bool] = None,
) -> Array:
    """KMM2 GEMM on pre-split s8 digit planes.

    a1, a0: (M, K) int8 high/low(-centered) digit planes of A.
    b1, b0: (K, N) int8 digit planes of B.
    Returns (M, N) int32 if ``combine_int32`` else float32.  Shapes must be
    multiples of the block sizes (ops.py pads).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = a1.shape
    _, n = b1.shape
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k, block_m, block_n, block_k))
    grid = (m // block_m, n // block_n, k // block_k)
    out_dtype = jnp.int32 if combine_int32 else jnp.float32
    kernel = functools.partial(
        _kmm2_kernel, h=h, nk=grid[2], combine_int32=combine_int32)
    a_spec = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)] * 3,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a1, a0, b1, b0)
