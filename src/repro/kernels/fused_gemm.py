"""Pallas TPU kernel: fused single-pass integer GEMM (MM1/KMM2/MM2/depth-2).

The paper's KMM hardware (Figs. 8-9) wins because the digit pre-adders, the
digit-plane multipliers and the post-adder combine live in *one* pipeline
with no intermediate memory round-trips.  The staged Pallas path in
:mod:`repro.kernels.ops` approximates that with ~6 HBM passes: ``_planes``
materializes plane arrays, ``kmm2_gemm_planes`` reads them back, and the
Section IV-D zero-point correction plus dequant each cost another
array-sized pass.  This kernel is the faithful mapping: ONE ``pallas_call``
that

  * reads the **original** integer operands (narrowest carrier: int8 for
    ``w <= m``, int16 up to ``w <= 16``, int32 above) — no pre-split planes
    in HBM;
  * performs the digit split(s) and low-digit centering on the VPU
    in-register, per (bm, bk)/(bk, bn) tile (the Fig. 8 X-adder vector);
  * runs the mode's MXU passes against persistent int32 VMEM accumulators
    across the K grid:

      - ``mm1``  (w <= m):        1 pass, no split;
      - ``kmm2`` (m < w <= 2m-2): 3 passes (C1, Cs, C0);
      - ``mm2``  (2m-2 < w <= 2m): 4 passes (C1, C10, C01, C0) — the
        conventional boundary mode, same accumulator scheme;
      - ``kmm4`` (depth-2 KMM, 4 digits): 9 passes — the level-1 centered
        split at ``h`` is re-split (plain, uncentered) at
        ``h2 = ceil((h+1)/2)`` per branch {A1, As, A0}, with the nested
        Fig. 8 pre-adders computed in-register on the VPU;

  * accumulates the zero-point rowsum/colsum terms in (bm, 1)/(1, bn) VMEM
    scratch across the K grid (``rowsum(Abar) = rowsum(A) - Kp*z`` needs the
    *raw* operand tiles, which the kernel already holds);
  * applies the mode's post-adder combine **and** the Section IV-D
    correction in the final K step, optionally followed by a dequant
    epilogue (per-token ``sx`` row scale x per-channel ``sw`` col scale ->
    fp32/bf16), so the quantized model path is 2 operand reads + 1 output
    write.

Numerics are pinned to the staged path bit-for-bit (asserted across the
pruned tune space by ``tests/test_fused_gemm.py`` / ``tests/test_tune.py``):
the digit products and row/col sums are exact int32 regardless of tiling,
and the fp32 combine applies the identical operation sequence as the staged
kernels at every level, so interpret-mode CI can gate the fused kernel
against the pure-jnp staged mirror with ``np.array_equal``.

``fused_gemm_grouped`` adds a leading expert/group grid axis so MoE expert
GEMMs ((E, C, K) x (E, K, N)) run as one kernel launch instead of an XLA
recursion per expert.  With ``counts``/``seg`` it runs *ragged*: row ``r``
of expert ``e`` is live iff ``r % seg < counts[e, r // seg]``; dead rows are
masked to exact zeros at the output (live rows never see the mask, so they
stay bit-identical to the dense grouped launch), and m-blocks with no live
row skip their MXU passes entirely.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

Array = jax.Array

# Kernel modes (digit layouts).  "auto" resolves to the paper's default for
# the width: mm1 (w <= m) or kmm2 (above).  mm2 and kmm4 are explicit
# because they are *alternatives* inside overlapping width windows (the
# dispatch/tuning layer owns the choice, not the kernel).
MODES = ("mm1", "kmm2", "mm2", "kmm4")


def _pad_tail(x: Array, mults) -> Array:
    """Zero-pad the trailing ``len(mults)`` dims of ``x`` up to multiples."""
    lead = x.ndim - len(mults)
    pads = [(0, 0)] * lead + [(0, (-x.shape[lead + i]) % mult)
                              for i, mult in enumerate(mults)]
    if any(p for _, p in pads):
        x = jnp.pad(x, pads)
    return x


def leaf_mag_bits(mode: str, w: int) -> int:
    """ceil(log2) bound on the largest digit magnitude entering an MXU pass
    (pre-adder outputs included) — the quantity that prices both the exact
    fp32-dot window and the int32 digit-accumulator headroom.

      * kmm2: |A1 + (A0 - z)| <= 2^h          (Fig. 8 pre-adder)
      * mm2:  |A1|, |A0 - z| <= 2^(h-1)       (no pre-adder)
      * kmm4: the level-1 branches fit h+1 signed bits; the plain level-2
        split at h2 = ceil((h+1)/2) gives leaves |hi| <= 2^(h-h2) and
        lo in [0, 2^h2), so the nested pre-adder is < 2^(h-h2) + 2^h2.
    """
    h = -(-w // 2)
    if mode == "kmm2":
        return h
    if mode == "mm2":
        return max(h - 1, 1)
    if mode == "kmm4":
        w1 = h + 1                       # widest branch: As = A1 + A0bar
        h2 = -(-w1 // 2)
        mag = (1 << max(w1 - h2 - 1, 0)) + (1 << h2)
        return max(mag.bit_length(), 1)
    raise ValueError(f"no digit magnitude for mode {mode!r}")


def _fp32_dot_ok(mode: str, w: int, block_k: int) -> bool:
    """Exact-fp32 digit products: every digit entering a dot is an integer
    with magnitude <= 2^leaf_mag_bits, so every K-dot partial sum over a
    block_k-deep tile is an integer of magnitude <= block_k * 2^(2*bits).
    While that stays <= 2^24 every value is exactly representable in fp32:
    the MXU-native fp32 pass computes the same integers the integer path
    does, bit for bit, and the int32 cast is lossless."""
    bits = leaf_mag_bits(mode, w)
    return block_k <= (1 << max(24 - 2 * bits, 0))


def _fused_kernel(*refs, mode: str, h: int, h2: int, z: int, nk: int,
                  kp: int, seg: int, fp32_dot: bool, combine_int32: bool,
                  dequant: bool, grouped: bool, ragged: bool, out_dtype):
    idx = 2
    a_ref, b_ref = refs[:2]
    sx_ref = sw_ref = counts_ref = None
    if dequant:
        sx_ref, sw_ref = refs[idx:idx + 2]
        idx += 2
    if ragged:
        counts_ref = refs[idx]
        idx += 1
    out_ref = refs[idx]
    scratch = refs[idx + 1:]
    k = pl.program_id(3 if grouped else 2)

    def ld(ref):
        return ref[0] if grouped else ref[...]

    @pl.when(k == 0)
    def _init():
        for r in scratch:
            r[...] = jnp.zeros_like(r)

    live = None
    if ragged:
        # Ragged grouped contract: row r is live iff its within-segment
        # rank beats the segment's live count.  The mask depends only on
        # (group, m-block) — dead m-blocks skip their MXU passes, dead
        # rows inside a live block are zeroed at the combine (live rows
        # never see the mask, so they match the dense launch bit-for-bit).
        bm = out_ref.shape[-2]
        n_seg = counts_ref.shape[-1]
        i = pl.program_id(1)
        rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
        seg_ids = rows // seg
        limit = jnp.take(counts_ref[0], jnp.clip(seg_ids, 0, n_seg - 1))
        live = (rows - seg_ids * seg < limit) & (seg_ids < n_seg)

    def _dots(pairs, accs):
        if fp32_dot:
            # Exact fp32 digit products (see _fp32_dot_ok): this is the
            # MXU's native number format; on CPU interpret mode it rides
            # the fast sgemm path instead of the integer-matmul fallback.
            hi_prec = jax.lax.Precision.HIGHEST
            for (x, y), acc in zip(pairs, accs):
                acc[...] += jnp.dot(x.astype(jnp.float32),
                                    y.astype(jnp.float32),
                                    precision=hi_prec).astype(jnp.int32)
        else:
            for (x, y), acc in zip(pairs, accs):
                acc[...] += jnp.dot(x, y, preferred_element_type=jnp.int32)

    def _accumulate():
        a = ld(a_ref)
        b = ld(b_ref)
        if mode == "mm1":
            (acc0_ref,) = scratch
            acc0_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.int32)
            return
        # VPU in-register digit split + centering (ops._planes, minus the
        # HBM plane arrays).  Digits stay in the operand carrier: their
        # values fit it with room to spare, so the MXU products are the
        # same exact int32 the staged plane kernels compute, without an
        # extra narrowing cast per tile.
        mask = (1 << h) - 1
        a1 = jnp.right_shift(a, h)
        a0 = jnp.bitwise_and(a, mask) - z
        b1 = jnp.right_shift(b, h)
        b0 = jnp.bitwise_and(b, mask) - z
        if mode == "kmm2":
            # Fig. 8 pre-adders + the three sub-MXU passes.
            pairs = [(a1, b1), (a1 + a0, b1 + b0), (a0, b0)]
        elif mode == "mm2":
            # Conventional 4-product boundary mode (no pre-adder, so the
            # digit planes stay within s8 up to w = 2m).
            pairs = [(a1, b1), (a1, b0), (a0, b1), (a0, b0)]
        else:  # kmm4: nested Fig. 8 — re-split each branch at h2, 9 passes
            mask2 = (1 << h2) - 1
            pairs = []
            for av, bv in ((a1, b1), (a1 + a0, b1 + b0), (a0, b0)):
                av1 = jnp.right_shift(av, h2)
                av0 = jnp.bitwise_and(av, mask2)
                bv1 = jnp.right_shift(bv, h2)
                bv0 = jnp.bitwise_and(bv, mask2)
                pairs += [(av1, bv1), (av1 + av0, bv1 + bv0), (av0, bv0)]
        row_ref, col_ref = scratch[-2], scratch[-1]
        _dots(pairs, scratch[:-2])
        # Zero-point sums accumulated across the K grid: rowsum(Abar) =
        # rowsum(A) - Kp*z, so the raw tiles already in registers suffice.
        row_ref[...] += jnp.sum(a, axis=1, keepdims=True, dtype=jnp.int32)
        col_ref[...] += jnp.sum(b, axis=0, keepdims=True, dtype=jnp.int32)

    if ragged:
        pl.when(jnp.any(live))(_accumulate)
    else:
        _accumulate()

    @pl.when(k == nk - 1)
    def _combine():
        if mode == "mm1":
            val = scratch[0][...]
        else:
            row = scratch[-2][...] - jnp.int32(kp * z)
            col = scratch[-1][...] - jnp.int32(kp * z)
            if mode == "kmm2":
                core = _combine_kmm2(scratch[0][...], scratch[1][...],
                                     scratch[2][...], h, combine_int32)
            elif mode == "mm2":
                core = _combine_mm2(scratch[0][...], scratch[1][...],
                                    scratch[2][...], scratch[3][...],
                                    h, combine_int32)
            else:  # kmm4: level-2 combine per branch, then level-1
                c11 = _combine_kmm2(scratch[0][...], scratch[1][...],
                                    scratch[2][...], h2, combine_int32)
                css = _combine_kmm2(scratch[3][...], scratch[4][...],
                                    scratch[5][...], h2, combine_int32)
                c00 = _combine_kmm2(scratch[6][...], scratch[7][...],
                                    scratch[8][...], h2, combine_int32)
                core = _combine_kmm2_wide(c11, css, c00, h, combine_int32)
            if combine_int32:
                val = core + (z * row + z * col + jnp.int32(z * z * kp))
            else:
                corr = (z * row.astype(jnp.float32)
                        + z * col.astype(jnp.float32)
                        + float(z) * float(z) * float(kp))
                val = core + corr
        if dequant:
            val = val.astype(jnp.float32) * (ld(sx_ref) * ld(sw_ref))
        val = val.astype(out_dtype)
        if ragged:
            val = jnp.where(live, val, jnp.zeros_like(val))
        if grouped:
            out_ref[0] = val
        else:
            out_ref[...] = val


def _combine_kmm2(c1, cs, c0, h: int, combine_int32: bool):
    """KMM post-adder (Fig. 9): C = C1<<2h + (Cs-C1-C0)<<h + C0 — the exact
    operation sequence of kmm2_gemm_planes / ref_kmm2_planes."""
    if combine_int32:
        return (c1 << (2 * h)) + ((cs - c1 - c0) << h) + c0
    c1f = c1.astype(jnp.float32)
    c0f = c0.astype(jnp.float32)
    mid = cs.astype(jnp.float32) - c1f - c0f
    return c1f * (2.0 ** (2 * h)) + mid * (2.0 ** h) + c0f


def _combine_kmm2_wide(c1, cs, c0, h: int, combine_int32: bool):
    """Level-1 KMM combine on already-combined (fp32/int32) branch products
    — same sequence as _combine_kmm2, minus the int32->fp32 casts."""
    if combine_int32:
        return (c1 << (2 * h)) + ((cs - c1 - c0) << h) + c0
    mid = cs - c1 - c0
    return c1 * (2.0 ** (2 * h)) + mid * (2.0 ** h) + c0


def _combine_mm2(c1, c10, c01, c0, h: int, combine_int32: bool):
    """Conventional 4-product combine — the exact operation sequence of
    mm2_gemm_planes / ref_mm2_planes (c10/c01 summed as fp32, not int)."""
    if combine_int32:
        return (c1 << (2 * h)) + ((c10 + c01) << h) + c0
    mid = c10.astype(jnp.float32) + c01.astype(jnp.float32)
    return (c1.astype(jnp.float32) * (2.0 ** (2 * h)) + mid * (2.0 ** h)
            + c0.astype(jnp.float32))


_N_ACC = {"mm1": 1, "kmm2": 3, "mm2": 4, "kmm4": 9}


def _resolve(w: int, m: int, mode: str, dequant: bool, combine_int32: bool,
             out_dtype, interpret: Optional[bool]):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if mode == "auto":
        mode = "mm1" if w <= m else "kmm2"
    if mode not in MODES:
        raise ValueError(f"unknown fused mode {mode!r}; choices {MODES}")
    split = mode != "mm1"
    h = -(-w // 2) if split else 0
    h2 = -(-(h + 1) // 2) if mode == "kmm4" else 0
    z = (1 << (h - 1)) if split else 0
    # Narrowest carrier covering the window: int8 for w <= m (one MXU pass,
    # no split), int16 through w = 16 (KMM2/MM2 windows), int32 only for
    # the deep-recursion widths — always at most half the staged wrapper's
    # int32 plane-materialization traffic.
    carrier = (jnp.int8 if not split else
               jnp.int16 if w <= 16 else jnp.int32)
    if out_dtype is None:
        out_dtype = (jnp.float32 if dequant else
                     jnp.int32 if (combine_int32 or not split) else
                     jnp.float32)
    return mode, h, h2, z, carrier, jnp.dtype(out_dtype), interpret


def _scratch_shapes(mode: str, block_m: int, block_n: int):
    accs = [pltpu.VMEM((block_m, block_n), jnp.int32)] * _N_ACC[mode]
    if mode == "mm1":
        return accs
    return accs + [pltpu.VMEM((block_m, 1), jnp.int32),
                   pltpu.VMEM((1, block_n), jnp.int32)]


def _fused_call(a, b, sx, sw, counts, *, grouped: bool, w: int, m: int,
                mode: str, seg: Optional[int], block_m: int, block_n: int,
                block_k: int, combine_int32: bool, out_dtype,
                interpret) -> Array:
    """Shared pallas_call builder; ``grouped`` adds the leading expert grid
    axis (every BlockSpec gains a size-1 leading block on the group index).
    """
    if (sx is None) != (sw is None):
        raise ValueError("pass both sx and sw for the dequant epilogue")
    dequant = sx is not None
    ragged = counts is not None
    if ragged and not grouped:
        raise ValueError("ragged counts require the grouped kernel")
    if ragged and (seg is None or seg <= 0):
        raise ValueError("ragged counts need a positive static seg")
    mode, h, h2, z, carrier, out_dtype, interpret = _resolve(
        w, m, mode, dequant, combine_int32, out_dtype, interpret)
    lead = a.shape[:-2]                  # () dense, (E,) grouped
    m_dim, k_dim = a.shape[-2:]
    n_dim = b.shape[-1]
    a = _pad_tail(a.astype(carrier), (block_m, block_k))
    b = _pad_tail(b.astype(carrier), (block_k, block_n))
    mp, kp = a.shape[-2:]
    np_ = b.shape[-1]
    body = (mp // block_m, np_ // block_n, kp // block_k)
    grid = lead + body if grouped else body

    def spec(block, index_map):
        if grouped:
            return pl.BlockSpec(
                (1,) + block,
                lambda g, i, j, kk, _f=index_map: (g,) + _f(i, j, kk))
        return pl.BlockSpec(block, index_map)

    kernel = functools.partial(
        _fused_kernel, mode=mode, h=h, h2=h2, z=z, nk=body[2], kp=kp,
        seg=seg or 0, fp32_dot=(mode != "mm1"
                                and _fp32_dot_ok(mode, w, block_k)),
        combine_int32=combine_int32, dequant=dequant, grouped=grouped,
        ragged=ragged, out_dtype=out_dtype)
    in_specs = [spec((block_m, block_k), lambda i, j, kk: (i, kk)),
                spec((block_k, block_n), lambda i, j, kk: (kk, j))]
    operands = [a, b]
    if dequant:
        operands += [_pad_tail(sx.astype(jnp.float32), (block_m, 1)),
                     _pad_tail(sw.astype(jnp.float32), (1, block_n))]
        in_specs += [spec((block_m, 1), lambda i, j, kk: (i, 0)),
                     spec((1, block_n), lambda i, j, kk: (0, j))]
    if ragged:
        n_seg = counts.shape[-1]
        operands.append(counts.astype(jnp.int32))
        in_specs.append(spec((n_seg,), lambda i, j, kk: (0,)))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=spec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct(lead + (mp, np_), out_dtype),
        scratch_shapes=_scratch_shapes(mode, block_m, block_n),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",) * (len(grid) - 1)
            + ("arbitrary",)),
        interpret=interpret,
    )(*operands)
    return out[..., :m_dim, :n_dim]


@functools.partial(
    jax.jit,
    static_argnames=("w", "m", "mode", "block_m", "block_n", "block_k",
                     "combine_int32", "out_dtype", "interpret"),
)
def fused_gemm(
    a: Array, b: Array, sx: Optional[Array] = None,
    sw: Optional[Array] = None, *,
    w: int,
    m: int = 8,
    mode: str = "auto",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    combine_int32: bool = False,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> Array:
    """Fused integer GEMM on the **original** (M, K) x (K, N) operands.

    ``a``/``b`` hold signed ``w``-bit values in any integer dtype; the
    wrapper zero-pads to tile multiples (padding commutes with the in-kernel
    correction: split(0) = (0, -z) and the K term uses padded K) and slices
    the result back.  ``mode`` picks the digit layout: ``"auto"`` resolves
    the paper's default (``w <= m`` -> single-pass MM1, above -> 3-pass
    KMM2); ``"mm2"`` runs the conventional 4-pass boundary mode (valid
    through ``w <= 2m``); ``"kmm4"`` runs depth-2 KMM (4 digits, 9 passes)
    whose per-leaf int32 accumulators stay exact to far deeper K than the
    single-level split (see ``tune.space.plan_accum_k_bound``).

    With ``sx`` (M, 1) / ``sw`` (1, N) fp32 scales the dequant epilogue
    ``out = acc * (sx * sw)`` runs in the same kernel (fp32, or ``out_dtype``
    e.g. bf16) — bit-identical to the staged ``acc * (sx * sw)``
    post-multiply.  Without scales the output is int32 for exact plans,
    fp32 otherwise.
    """
    return _fused_call(a, b, sx, sw, None, grouped=False, w=w, m=m,
                       mode=mode, seg=None, block_m=block_m, block_n=block_n,
                       block_k=block_k, combine_int32=combine_int32,
                       out_dtype=out_dtype, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("w", "m", "mode", "seg", "block_m", "block_n",
                     "block_k", "combine_int32", "out_dtype", "interpret"),
)
def fused_gemm_grouped(
    a: Array, b: Array, sx: Optional[Array] = None,
    sw: Optional[Array] = None, counts: Optional[Array] = None, *,
    w: int,
    m: int = 8,
    mode: str = "auto",
    seg: Optional[int] = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    combine_int32: bool = False,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> Array:
    """Grouped/batched :func:`fused_gemm`: (E, C, K) x (E, K, N) -> (E, C, N).

    The expert axis is a leading parallel grid dimension, so all expert
    GEMMs of an MoE layer run inside one kernel launch (one set of jits, no
    per-expert dispatch).  Scales, when given, are (E, C, 1) and (E, 1, N).
    Per-group results are bit-identical to E independent ``fused_gemm``
    calls with the same tiles.

    ``counts`` (E, S) int32 with static ``seg`` makes the launch *ragged*
    (MegaBlocks-style): the C rows of expert ``e`` are read as S segments of
    ``seg`` rows each, of which only the first ``counts[e, s]`` are live.
    Dead rows come out as exact zeros; live rows are bit-identical to the
    dense launch with the same tiles (the mask touches outputs, never the
    accumulation), and m-blocks with no live row skip their MXU passes —
    the capacity-bucketed MoE dispatch (models/moe.py) passes S = batch,
    seg = capacity.  A zero-count segment (zero-token expert) is all-dead.
    """
    return _fused_call(a, b, sx, sw, counts, grouped=True, w=w, m=m,
                       mode=mode, seg=seg, block_m=block_m, block_n=block_n,
                       block_k=block_k, combine_int32=combine_int32,
                       out_dtype=out_dtype, interpret=interpret)
