"""Pallas TPU kernel: fused single-pass KMM2/MM1 integer GEMM.

The paper's KMM hardware (Figs. 8-9) wins because the digit pre-adders, the
three digit-plane multipliers and the post-adder combine live in *one*
pipeline with no intermediate memory round-trips.  The staged Pallas path in
:mod:`repro.kernels.ops` approximates that with ~6 HBM passes: ``_planes``
materializes four int8 plane arrays, ``kmm2_gemm_planes`` reads them back,
and the Section IV-D zero-point correction plus dequant each cost another
array-sized pass.  This kernel is the faithful mapping: ONE ``pallas_call``
that

  * reads the **original** integer operands (narrowest carrier: int8 for
    ``w <= m``, int16 for the KMM2 window) — no pre-split planes in HBM;
  * performs the ``h``-split and low-digit centering on the VPU in-register,
    per (bm, bk)/(bk, bn) tile (the Fig. 8 X-adder vector);
  * runs the three digit MXU passes (C1, Cs, C0) against persistent int32
    VMEM accumulators across the K grid — or a single pass when ``w <= m``
    (MM1 window, no split needed);
  * accumulates the zero-point rowsum/colsum terms in (bm, 1)/(1, bn) VMEM
    scratch across the K grid (``rowsum(Abar) = rowsum(A) - Kp*z`` needs the
    *raw* operand tiles, which the kernel already holds);
  * applies the KMM post-adder combine **and** the Section IV-D correction
    in the final K step, optionally followed by a dequant epilogue
    (per-token ``sx`` row scale x per-channel ``sw`` col scale ->
    fp32/bf16), so the quantized model path is 2 operand reads + 1 output
    write.

Numerics are pinned to the staged path bit-for-bit (asserted across the
pruned tune space by ``tests/test_fused_gemm.py`` / ``tests/test_tune.py``):
the digit products and row/col sums are exact int32 regardless of tiling,
and the fp32 combine applies the identical operation sequence
(``c1*2^2h + (cs-c1-c0)*2^h + c0`` then ``+ (z*row + z*col + z*z*Kp)``), so
interpret-mode CI can gate the fused kernel against the pure-jnp staged
mirror with ``np.array_equal``.

``fused_gemm_grouped`` adds a leading expert/group grid axis so MoE expert
GEMMs ((E, C, K) x (E, K, N)) run as one kernel launch instead of an XLA
recursion per expert.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

Array = jax.Array


def _pad_tail(x: Array, mults) -> Array:
    """Zero-pad the trailing ``len(mults)`` dims of ``x`` up to multiples."""
    lead = x.ndim - len(mults)
    pads = [(0, 0)] * lead + [(0, (-x.shape[lead + i]) % mult)
                              for i, mult in enumerate(mults)]
    if any(p for _, p in pads):
        x = jnp.pad(x, pads)
    return x


def _fused_kernel(*refs, h: int, z: int, nk: int, kp: int, split: bool,
                  fp32_dot: bool, combine_int32: bool, dequant: bool,
                  grouped: bool, out_dtype):
    if dequant:
        a_ref, b_ref, sx_ref, sw_ref, out_ref = refs[:5]
        scratch = refs[5:]
    else:
        a_ref, b_ref, out_ref = refs[:3]
        scratch = refs[3:]
    k = pl.program_id(3 if grouped else 2)

    def ld(ref):
        return ref[0] if grouped else ref[...]

    @pl.when(k == 0)
    def _init():
        for r in scratch:
            r[...] = jnp.zeros_like(r)

    a = ld(a_ref)
    b = ld(b_ref)
    if split:
        acc1_ref, accs_ref, acc0_ref, row_ref, col_ref = scratch
        mask = (1 << h) - 1
        # VPU in-register digit split + centering (ops._planes, minus the
        # four HBM plane arrays).  Digits stay in the int16 operand carrier:
        # their values fit s8 (w <= 16), so the MXU products are the same
        # exact int32 the staged s8-plane kernel computes, without an extra
        # narrowing cast per tile.
        a1 = jnp.right_shift(a, h)
        a0 = jnp.bitwise_and(a, mask) - z
        b1 = jnp.right_shift(b, h)
        b0 = jnp.bitwise_and(b, mask) - z
        # Fig. 8 pre-adders (s8-safe within the KMM2 window w <= 2m-2) and
        # the three sub-MXU passes with persistent int32 accumulation.
        if fp32_dot:
            # Exact fp32 digit products (see fused_gemm: digits are
            # integers <= 2^h, so with block_k <= 2^(24-2h) every partial
            # sum is an integer below 2^24 — fp32 arithmetic is exact and
            # the int32 cast is lossless).  This is the MXU's native
            # number format; on CPU interpret mode it rides the fast
            # sgemm path instead of the integer-matmul fallback.
            a1f, a0f = a1.astype(jnp.float32), a0.astype(jnp.float32)
            b1f, b0f = b1.astype(jnp.float32), b0.astype(jnp.float32)
            hi = jax.lax.Precision.HIGHEST
            acc1_ref[...] += jnp.dot(a1f, b1f,
                                     precision=hi).astype(jnp.int32)
            accs_ref[...] += jnp.dot(a1f + a0f, b1f + b0f,
                                     precision=hi).astype(jnp.int32)
            acc0_ref[...] += jnp.dot(a0f, b0f,
                                     precision=hi).astype(jnp.int32)
        else:
            acc1_ref[...] += jnp.dot(a1, b1,
                                     preferred_element_type=jnp.int32)
            accs_ref[...] += jnp.dot(a1 + a0, b1 + b0,
                                     preferred_element_type=jnp.int32)
            acc0_ref[...] += jnp.dot(a0, b0,
                                     preferred_element_type=jnp.int32)
        # Zero-point sums accumulated across the K grid: rowsum(Abar) =
        # rowsum(A) - Kp*z, so the raw tiles already in registers suffice.
        row_ref[...] += jnp.sum(a, axis=1, keepdims=True, dtype=jnp.int32)
        col_ref[...] += jnp.sum(b, axis=0, keepdims=True, dtype=jnp.int32)
    else:
        (acc0_ref,) = scratch
        acc0_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _combine():
        if split:
            c1 = acc1_ref[...]
            cs = accs_ref[...]
            c0 = acc0_ref[...]
            row = row_ref[...] - jnp.int32(kp * z)
            col = col_ref[...] - jnp.int32(kp * z)
            if combine_int32:
                core = (c1 << (2 * h)) + ((cs - c1 - c0) << h) + c0
                val = core + (z * row + z * col + jnp.int32(z * z * kp))
            else:
                c1f = c1.astype(jnp.float32)
                c0f = c0.astype(jnp.float32)
                mid = cs.astype(jnp.float32) - c1f - c0f
                core = c1f * (2.0 ** (2 * h)) + mid * (2.0 ** h) + c0f
                corr = (z * row.astype(jnp.float32)
                        + z * col.astype(jnp.float32)
                        + float(z) * float(z) * float(kp))
                val = core + corr
        else:
            val = acc0_ref[...]
        if dequant:
            val = val.astype(jnp.float32) * (ld(sx_ref) * ld(sw_ref))
        val = val.astype(out_dtype)
        if grouped:
            out_ref[0] = val
        else:
            out_ref[...] = val


def _fp32_dot_ok(h: int, block_k: int) -> bool:
    """Exact-fp32 digit products: digits (incl. the pre-adder outputs) are
    integers with magnitude <= 2^h, so every K-dot partial sum over a
    block_k-deep tile is an integer of magnitude <= block_k * 2^(2h).
    While that stays <= 2^24 every value is exactly representable in fp32:
    the MXU-native fp32 pass computes the same integers the s8 path does,
    bit for bit, and the int32 cast is lossless."""
    return block_k <= (1 << max(24 - 2 * h, 0))


def _resolve(w: int, m: int, dequant: bool, combine_int32: bool, out_dtype,
             interpret: Optional[bool]):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    split = w > m
    h = -(-w // 2) if split else 0
    z = (1 << (h - 1)) if split else 0
    # Narrowest carrier covering the fused windows: int8 for w <= m (one
    # MXU pass, no split), int16 for the KMM2 window (w <= 2m - 2 = 14) —
    # half the HBM operand traffic of the int32 carrier the staged wrapper
    # hauls through its plane-materialization passes.
    carrier = jnp.int16 if split else jnp.int8
    if out_dtype is None:
        out_dtype = (jnp.float32 if dequant else
                     jnp.int32 if (combine_int32 or not split) else
                     jnp.float32)
    return split, h, z, carrier, jnp.dtype(out_dtype), interpret


def _scratch_shapes(split: bool, block_m: int, block_n: int):
    if not split:
        return [pltpu.VMEM((block_m, block_n), jnp.int32)]
    return [pltpu.VMEM((block_m, block_n), jnp.int32)] * 3 + [
        pltpu.VMEM((block_m, 1), jnp.int32),
        pltpu.VMEM((1, block_n), jnp.int32),
    ]


def _fused_call(a, b, sx, sw, *, grouped: bool, w: int, m: int,
                block_m: int, block_n: int, block_k: int,
                combine_int32: bool, out_dtype, interpret) -> Array:
    """Shared pallas_call builder; ``grouped`` adds the leading expert grid
    axis (every BlockSpec gains a size-1 leading block on the group index).
    """
    if (sx is None) != (sw is None):
        raise ValueError("pass both sx and sw for the dequant epilogue")
    dequant = sx is not None
    split, h, z, carrier, out_dtype, interpret = _resolve(
        w, m, dequant, combine_int32, out_dtype, interpret)
    lead = a.shape[:-2]                  # () dense, (E,) grouped
    m_dim, k_dim = a.shape[-2:]
    n_dim = b.shape[-1]
    a = _pad_tail(a.astype(carrier), (block_m, block_k))
    b = _pad_tail(b.astype(carrier), (block_k, block_n))
    mp, kp = a.shape[-2:]
    np_ = b.shape[-1]
    body = (mp // block_m, np_ // block_n, kp // block_k)
    grid = lead + body if grouped else body

    def spec(block, index_map):
        if grouped:
            return pl.BlockSpec(
                (1,) + block,
                lambda g, i, j, kk, _f=index_map: (g,) + _f(i, j, kk))
        return pl.BlockSpec(block, index_map)

    kernel = functools.partial(
        _fused_kernel, h=h, z=z, nk=body[2], kp=kp, split=split,
        fp32_dot=split and _fp32_dot_ok(h, block_k),
        combine_int32=combine_int32, dequant=dequant, grouped=grouped,
        out_dtype=out_dtype)
    in_specs = [spec((block_m, block_k), lambda i, j, kk: (i, kk)),
                spec((block_k, block_n), lambda i, j, kk: (kk, j))]
    operands = [a, b]
    if dequant:
        operands += [_pad_tail(sx.astype(jnp.float32), (block_m, 1)),
                     _pad_tail(sw.astype(jnp.float32), (1, block_n))]
        in_specs += [spec((block_m, 1), lambda i, j, kk: (i, 0)),
                     spec((1, block_n), lambda i, j, kk: (0, j))]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=spec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct(lead + (mp, np_), out_dtype),
        scratch_shapes=_scratch_shapes(split, block_m, block_n),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",) * (len(grid) - 1)
            + ("arbitrary",)),
        interpret=interpret,
    )(*operands)
    return out[..., :m_dim, :n_dim]


@functools.partial(
    jax.jit,
    static_argnames=("w", "m", "block_m", "block_n", "block_k",
                     "combine_int32", "out_dtype", "interpret"),
)
def fused_gemm(
    a: Array, b: Array, sx: Optional[Array] = None,
    sw: Optional[Array] = None, *,
    w: int,
    m: int = 8,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    combine_int32: bool = False,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> Array:
    """Fused integer GEMM on the **original** (M, K) x (K, N) operands.

    ``a``/``b`` hold signed ``w``-bit values in any integer dtype; the
    wrapper zero-pads to tile multiples (padding commutes with the in-kernel
    correction: split(0) = (0, -z) and the K term uses padded K) and slices
    the result back.  ``w <= m`` runs the single-pass MM1 window (core is
    inherently exact int32, ``combine_int32`` is ignored); ``m < w <= 2m-2``
    runs the 3-pass KMM2 window.

    With ``sx`` (M, 1) / ``sw`` (1, N) fp32 scales the dequant epilogue
    ``out = acc * (sx * sw)`` runs in the same kernel (fp32, or ``out_dtype``
    e.g. bf16) — bit-identical to the staged ``acc * (sx * sw)``
    post-multiply.  Without scales the output is int32 for exact plans,
    fp32 otherwise.
    """
    return _fused_call(a, b, sx, sw, grouped=False, w=w, m=m,
                       block_m=block_m, block_n=block_n, block_k=block_k,
                       combine_int32=combine_int32, out_dtype=out_dtype,
                       interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("w", "m", "block_m", "block_n", "block_k",
                     "combine_int32", "out_dtype", "interpret"),
)
def fused_gemm_grouped(
    a: Array, b: Array, sx: Optional[Array] = None,
    sw: Optional[Array] = None, *,
    w: int,
    m: int = 8,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    combine_int32: bool = False,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> Array:
    """Grouped/batched :func:`fused_gemm`: (E, C, K) x (E, K, N) -> (E, C, N).

    The expert axis is a leading parallel grid dimension, so all expert
    GEMMs of an MoE layer run inside one kernel launch (one set of jits, no
    per-expert dispatch).  Scales, when given, are (E, C, 1) and (E, 1, N).
    Per-group results are bit-identical to E independent ``fused_gemm``
    calls with the same tiles.
    """
    return _fused_call(a, b, sx, sw, grouped=True, w=w, m=m,
                       block_m=block_m, block_n=block_n, block_k=block_k,
                       combine_int32=combine_int32, out_dtype=out_dtype,
                       interpret=interpret)
