"""High-level integer GEMM entry point: dispatch + digit planes + corrections.

``int_gemm(a, b, w)`` is the production API: signed w-bit integer operands
(carried in int32) multiplied exactly through the mode the paper's
precision-scalable rule selects (MM1 / KMM2 / MM2), on either the Pallas MXU
kernels (``backend="pallas"``) or plain XLA dot_generals (``backend="xla"``,
the default — used inside pjit'd model code so SPMD partitioning and the
dry-run cost analysis see ordinary dots).

Execution is plan-driven: :func:`repro.core.dispatch.select_plan` resolves an
:class:`~repro.core.dispatch.ExecPlan` (variant, tiles, combine precision,
recursion depth) — from the paper's analytic rule by default, or from the
active :mod:`repro.tune` table when one is installed — and :func:`run_plan`
executes it.  ``run_plan(..., use_ref_kernels=True)`` swaps the Pallas digit
kernels for their pure-jnp mirrors in :mod:`repro.kernels.ref` while keeping
the padding/correction wrapper identical, which is the bit-exact oracle the
autotuner checks every candidate against.

The Pallas backend's default route is the fused single-pass kernel
(kernels/fused_gemm.py, DESIGN.md §11): digit split, MXU passes, zero-point
correction and optional dequant epilogue inside one pallas_call.  The staged
pipeline below (_int_gemm_pallas: _planes in HBM -> digit kernel ->
correction) remains as the MM2/deep-recursion fallback and as the fused
kernel's bit-exact oracle wrapper (``use_ref_kernels=True``).

Digit handling for the Pallas path (see kmm_gemm.py): split at h = ceil(w/2),
center the low digit by z = 2^(h-1) so all planes are s8, then fold the
centering back with the paper's zero-point-adjuster correction:

    A@B = Abar@Bbar + z*rowsum(Abar) + z*colsum(Bbar) + K*z^2

(rowsum broadcast over columns, colsum over rows).  Zero padding commutes
with the correction because split(0) = (0, -z) and the K term uses padded K.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dispatch import ExecPlan, Mode, select_plan
from repro.core.strassen import STRASSEN_VARIANTS, strassen_matmul
from repro.obs import trace as obs_trace
from repro.core.kmm import kmm_n, mm_n, max_exact_k
from repro.kernels.ffip import ffip_gemm_literal
from repro.kernels.fused_gemm import fused_gemm
from repro.kernels.kmm_gemm import kmm2_gemm_planes
from repro.kernels.mm1_gemm import mm1_gemm
from repro.kernels.mm2_gemm import mm2_gemm_planes
from repro.kernels.ref import ref_int_gemm, ref_kmm2_planes, ref_mm2_planes

Array = jax.Array


def _pad_to(x: Array, mult0: int, mult1: int) -> Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _planes(x: Array, h: int):
    z = 1 << (h - 1)
    xi = x.astype(jnp.int32)
    hi = jnp.right_shift(xi, h).astype(jnp.int8)
    lo = (jnp.bitwise_and(xi, (1 << h) - 1) - z).astype(jnp.int8)
    return hi, lo, z


def int_gemm(
    a: Array,
    b: Array,
    *,
    w: int,
    m: int = 8,
    backend: str = "xla",
    exact: bool = False,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
    plan: Optional[ExecPlan] = None,
    context=None,
) -> Array:
    """Integer GEMM with precision-scalable dispatch (paper Fig. 10).

    a: (M, K) signed w-bit values in an integer dtype; b: (K, N) likewise.
    Returns float32 (or int32 when ``exact=True``, which asserts the int32
    exactness bound 2w + log2(K) + 2 <= 31 and uses integer combines).

    Tile sizes default to the active tuning table's winner for this
    (backend, M/N/K bucket, w) key — or (128, 128, 256) when no table is
    installed; explicit ``block_*`` arguments always win.  ``plan`` bypasses
    selection entirely and executes the given :class:`ExecPlan` (the
    autotuner's entry point).

    ``context`` (an :class:`repro.core.context.ExecContext`) supplies
    backend / tuning table / mesh in one object; with ``context.mesh`` set
    and the pallas backend, the kernel runs shard-mapped over the mesh
    (:mod:`repro.dist.shard_gemm`) on negotiated M/N axes.  The mesh is
    never inferred from ambient state here — collective helpers that call
    ``int_gemm`` from inside their own ``shard_map`` stay single-shard.
    """
    if context is not None:
        backend = context.backend
    if backend not in ("xla", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    m_dim, k_dim = a.shape
    n_dim = b.shape[1]
    if exact and max_exact_k(w) < k_dim:
        raise ValueError(
            f"exact int32 output impossible for w={w}, K={k_dim}; "
            f"max exact K is {max_exact_k(w)}")
    if plan is None:
        plan = select_plan((m_dim, k_dim, n_dim), w, m=m, backend=backend,
                           exact=exact, context=context)
        overrides = {k: v for k, v in (("block_m", block_m),
                                       ("block_n", block_n),
                                       ("block_k", block_k)) if v is not None}
        if overrides:
            plan = dataclasses.replace(plan, **overrides)
    mesh = context.mesh if context is not None else None
    out = run_plan(a, b, plan=plan, interpret=interpret, mesh=mesh)
    if exact:
        return out
    return out if out.dtype == jnp.float32 else out.astype(jnp.float32)


def run_plan(a: Array, b: Array, *, plan: ExecPlan,
             interpret: Optional[bool] = None,
             use_ref_kernels: bool = False,
             mesh=None, context=None) -> Array:
    """Execute one :class:`ExecPlan` on (M, K) x (K, N) integer operands.

    Output dtype follows the plan: int32 for exact-int plans
    (``plan.is_exact_int``), float32 for fp32-combine plans.
    ``use_ref_kernels`` routes the digit-plane products through the pure-jnp
    mirrors in :mod:`repro.kernels.ref` instead of the Pallas kernels —
    identical padding/correction wrapper, bit-identical result — giving the
    tuner its correctness oracle.

    With ``mesh`` (or ``context.mesh``) set and a pallas-backend plan, the
    plan executes shard-mapped (:func:`repro.dist.shard_gemm
    .sharded_run_plan`): each shard runs the identical kernel on its local
    block — covering the fused kernel AND the staged fallback variants —
    with M/N axes from ``plan.shard`` (negotiated when unset).  XLA-backend
    plans ignore the mesh (plain dot_generals partition via GSPMD).
    """
    if not obs_trace.enabled():
        return _run_plan_impl(a, b, plan=plan, interpret=interpret,
                              use_ref_kernels=use_ref_kernels, mesh=mesh,
                              context=context)
    # Host-side span: inside a jit this fires once per TRACE (a build-time
    # record, never a per-call device sync); eager calls time the dispatch.
    with obs_trace.span("run_plan", variant=plan.variant, w=plan.w,
                        backend=plan.backend, depth=plan.depth,
                        shape=f"{a.shape[0]}x{a.shape[1]}x{b.shape[-1]}"):
        return _run_plan_impl(a, b, plan=plan, interpret=interpret,
                              use_ref_kernels=use_ref_kernels, mesh=mesh,
                              context=context)


def _run_plan_impl(a: Array, b: Array, *, plan: ExecPlan,
                   interpret: Optional[bool] = None,
                   use_ref_kernels: bool = False,
                   mesh=None, context=None) -> Array:
    if mesh is None and context is not None:
        mesh = context.mesh
    if mesh is not None and plan.backend == "pallas" \
            and not getattr(mesh, "empty", False):
        from repro.dist.shard_gemm import sharded_run_plan
        return sharded_run_plan(a, b, plan=plan, mesh=mesh,
                                interpret=interpret,
                                use_ref_kernels=use_ref_kernels)
    if plan.shard is not None:
        plan = dataclasses.replace(plan, shard=None)
    if plan.variant in STRASSEN_VARIANTS:
        # Tile-level Strassen split (core/strassen.py): the 7 sub-GEMMs
        # re-enter this dispatcher with the derived sub-plan, so they ride
        # the full stack — fused Pallas kernels, interpret mode and the
        # ref-kernel oracle mirror included.
        def run_sub(x, y, sub_plan):
            return _run_plan_impl(x, y, plan=sub_plan, interpret=interpret,
                                  use_ref_kernels=use_ref_kernels)
        return strassen_matmul(a, b, plan=plan, run_sub=run_sub)
    if plan.variant == "xla_ref":
        return ref_int_gemm(a, b)
    if plan.variant == "ffip":
        return ffip_gemm_literal(a, b)
    if plan.variant in ("fused", "fused_mm2"):
        if use_ref_kernels:
            # The staged pure-jnp mirror IS the fused kernel's oracle: the
            # fused plan's mode/depth/tiles drive the identical padding +
            # zero-point-correction wrapper below (incl. the staged depth-2
            # branch and the MM2 plane mirror).
            return _int_gemm_pallas(a, b, plan=plan, interpret=interpret,
                                    use_ref_kernels=True)
        bm, bn, bk = plan.tiles
        mode = ("mm2" if plan.variant == "fused_mm2" else
                "kmm4" if plan.depth == 2 else "auto")
        return fused_gemm(a, b, w=plan.w, m=plan.m, mode=mode, block_m=bm,
                          block_n=bn, block_k=bk,
                          combine_int32=plan.combine_int32,
                          interpret=interpret)
    if plan.backend == "xla":
        return _int_gemm_xla(a, b, plan=plan)
    return _int_gemm_pallas(a, b, plan=plan, interpret=interpret,
                            use_ref_kernels=use_ref_kernels)


@functools.partial(jax.jit,
                   static_argnames=("plan", "interpret", "use_ref_kernels",
                                    "mesh", "context"))
def run_plan_jit(a: Array, b: Array, plan: ExecPlan,
                 interpret: Optional[bool] = None,
                 use_ref_kernels: bool = False,
                 mesh=None, context=None) -> Array:
    """jit'd :func:`run_plan` (ExecPlan is frozen/hashable, so it is a
    static arg — one trace per plan).  ``mesh``/``context`` are static too
    (Mesh and ExecContext both hash; the context's table is excluded from
    its hash and is irrelevant here — the plan is already resolved)."""
    return run_plan(a, b, plan=plan, interpret=interpret,
                    use_ref_kernels=use_ref_kernels, mesh=mesh,
                    context=context)


def _int_gemm_xla(a: Array, b: Array, *, plan: ExecPlan) -> Array:
    combine = jnp.int32 if plan.combine_int32 else jnp.float32
    ai, bi = a.astype(jnp.int32), b.astype(jnp.int32)
    if plan.mode is Mode.MM1:
        return jax.lax.dot_general(ai, bi, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)
    fn = kmm_n if plan.mode is Mode.KMM2 else mm_n
    return fn(ai, bi, w=plan.w, n=plan.digits, combine_dtype=combine)


def _int_gemm_pallas(a: Array, b: Array, *, plan: ExecPlan,
                     interpret: Optional[bool],
                     use_ref_kernels: bool = False) -> Array:
    m_dim, k_dim = a.shape
    n_dim = b.shape[1]
    block_m, block_n, block_k = plan.tiles
    exact = plan.combine_int32
    a = _pad_to(a.astype(jnp.int32), block_m, block_k)
    b = _pad_to(b.astype(jnp.int32), block_k, block_n)
    kp = a.shape[1]
    if plan.mode is Mode.MM1:
        if use_ref_kernels:
            out = ref_int_gemm(a.astype(jnp.int8), b.astype(jnp.int8))
        else:
            out = mm1_gemm(a.astype(jnp.int8), b.astype(jnp.int8),
                           block_m=block_m, block_n=block_n, block_k=block_k,
                           interpret=interpret)
        return out[:m_dim, :n_dim]
    h = -(-plan.w // 2)
    z = 1 << (h - 1)
    if plan.depth == 2 and plan.mode is Mode.KMM2:
        core = _kmm4_core(a, b, h=h, z=z, exact=exact, tiles=plan.tiles,
                          interpret=interpret,
                          use_ref_kernels=use_ref_kernels)
    elif plan.depth > 1:
        raise NotImplementedError(
            "pallas backend implements KMM recursion up to depth 2 "
            "(plus single-level MM2); use backend='xla' for deeper "
            "recursion")
    else:
        a1, a0, _ = _planes(a, h)
        b1, b0, _ = _planes(b, h)
        if use_ref_kernels:
            ref = ref_kmm2_planes if plan.mode is Mode.KMM2 \
                else ref_mm2_planes
            core = ref(a1, a0, b1, b0, h=h, combine_int32=exact)
        else:
            kernel = kmm2_gemm_planes if plan.mode is Mode.KMM2 \
                else mm2_gemm_planes
            core = kernel(a1, a0, b1, b0, h=h, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          combine_int32=exact, interpret=interpret)
    # Zero-point adjuster (paper Section IV-D / prior work [6]).  The digit
    # identity abar = a - z (elementwise, padded zeros included) gives the
    # correction sums directly from the padded operands — no abar/bbar
    # reconstruction, two fewer full-array passes; values are int32-exact
    # and bit-identical to summing the rebuilt planes.
    row = (jnp.sum(a, axis=1, keepdims=True, dtype=jnp.int32)
           - jnp.int32(kp * z))               # (M, 1) rowsum(abar)
    col = (jnp.sum(b, axis=0, keepdims=True, dtype=jnp.int32)
           - jnp.int32(kp * z))               # (1, N) colsum(bbar)
    if exact:
        corr = z * row + z * col + jnp.int32(z * z * kp)
        out = core + corr
    else:
        corr = (z * row.astype(jnp.float32) + z * col.astype(jnp.float32)
                + float(z) * float(z) * float(kp))
        out = core + corr
    return out[:m_dim, :n_dim]


def _kmm4_core(a: Array, b: Array, *, h: int, z: int, exact: bool, tiles,
               interpret: Optional[bool], use_ref_kernels: bool) -> Array:
    """Staged depth-2 KMM core on padded int32 operands: three branch KMM2
    plane launches at the level-2 split + the level-1 combine in jnp.

    The level-1 centered split at ``h`` yields branches {A1, A1+A0bar,
    A0bar} (each fits h+1 signed bits); each branch is re-split *plain*
    (uncentered — exact in two's complement, so no per-branch zero-point
    correction) at ``h2 = ceil((h+1)/2)`` into int16 planes that the
    single-level KMM2 kernel consumes unchanged.  Operation sequences match
    the fused kmm4 kernel level for level, so fp32 combines are
    bit-identical; the caller applies the one level-1 zero-point
    correction.
    """
    block_m, block_n, block_k = tiles
    mask = (1 << h) - 1
    a1 = jnp.right_shift(a, h)
    a0 = jnp.bitwise_and(a, mask) - z
    b1 = jnp.right_shift(b, h)
    b0 = jnp.bitwise_and(b, mask) - z
    h2 = -(-(h + 1) // 2)
    mask2 = (1 << h2) - 1

    def branch(av, bv):
        av1 = jnp.right_shift(av, h2).astype(jnp.int16)
        av0 = jnp.bitwise_and(av, mask2).astype(jnp.int16)
        bv1 = jnp.right_shift(bv, h2).astype(jnp.int16)
        bv0 = jnp.bitwise_and(bv, mask2).astype(jnp.int16)
        if use_ref_kernels:
            return ref_kmm2_planes(av1, av0, bv1, bv0, h=h2,
                                   combine_int32=exact)
        return kmm2_gemm_planes(av1, av0, bv1, bv0, h=h2, block_m=block_m,
                                block_n=block_n, block_k=block_k,
                                combine_int32=exact, interpret=interpret)

    c11 = branch(a1, b1)
    css = branch(a1 + a0, b1 + b0)
    c00 = branch(a0, b0)
    if exact:
        return (c11 << (2 * h)) + ((css - c11 - c00) << h) + c00
    mid = css - c11 - c00
    return c11 * (2.0 ** (2 * h)) + mid * (2.0 ** h) + c00


@functools.partial(jax.jit, static_argnames=("w", "m", "backend", "exact"))
def int_gemm_jit(a: Array, b: Array, w: int, m: int = 8,
                 backend: str = "xla", exact: bool = False) -> Array:
    return int_gemm(a, b, w=w, m=m, backend=backend, exact=exact)


def quantize_symmetric(x: Array, w: int, axis=None):
    """Symmetric signed w-bit quantization. Returns (q_int32, scale_f32).

    Thin alias for :func:`repro.quant.quantize.quantize_symmetric` — the one
    shared recipe (imported lazily: ``repro.quant``'s package init imports
    qmatmul, which imports the fused kernel from this package)."""
    from repro.quant.quantize import quantize_symmetric as _qs
    return _qs(x, w, axis=axis)
