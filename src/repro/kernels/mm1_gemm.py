"""Pallas TPU kernel: MM1 int8 GEMM (paper Fig. 7 baseline MXU).

The single-pass baseline for w <= m = 8: one int8 MXU product per tile with
one int32 VMEM accumulator.  The MXU dot over block_k is the Algorithm-5
pre-accumulation (p = block_k); the persistent accumulator sees one add per
K tile (the single wide add of Fig. 6).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

Array = jax.Array


def _mm1_kernel(a_ref, b_ref, out_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _store():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def mm1_gemm(
    a: Array, b: Array, *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: Optional[bool] = None,
) -> Array:
    """int8 (M, K) @ (K, N) -> int32, exact."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = a.shape
    _, n = b.shape
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k, block_m, block_n, block_k))
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_mm1_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
