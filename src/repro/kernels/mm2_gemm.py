"""Pallas TPU kernel: conventional MM2 integer GEMM (paper Algorithm 3 baseline).

Identical structure to :mod:`repro.kernels.kmm_gemm` but with the conventional
FOUR digit-plane products (C1, C10, C01, C0) and four int32 VMEM accumulators
— the baseline against which KMM2's 3-pass / 3-accumulator advantage is
measured (25% fewer MXU passes, 25% less accumulator VMEM).  Valid for
w <= 2m = 16 with centered digits.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

Array = jax.Array


def _mm2_kernel(a1_ref, a0_ref, b1_ref, b0_ref, out_ref,
                acc1_ref, acc10_ref, acc01_ref, acc0_ref, *, h: int, nk: int,
                combine_int32: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc10_ref[...] = jnp.zeros_like(acc10_ref)
        acc01_ref[...] = jnp.zeros_like(acc01_ref)
        acc0_ref[...] = jnp.zeros_like(acc0_ref)

    a1 = a1_ref[...]
    a0 = a0_ref[...]
    b1 = b1_ref[...]
    b0 = b0_ref[...]
    # Four sub-MXU passes (Fig. 3): the conventional digit cross-products.
    acc1_ref[...] += jnp.dot(a1, b1, preferred_element_type=jnp.int32)
    acc10_ref[...] += jnp.dot(a1, b0, preferred_element_type=jnp.int32)
    acc01_ref[...] += jnp.dot(a0, b1, preferred_element_type=jnp.int32)
    acc0_ref[...] += jnp.dot(a0, b0, preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _combine():
        c1 = acc1_ref[...]
        c10 = acc10_ref[...]
        c01 = acc01_ref[...]
        c0 = acc0_ref[...]
        if combine_int32:
            out_ref[...] = (c1 << (2 * h)) + ((c10 + c01) << h) + c0
        else:
            mid = c10.astype(jnp.float32) + c01.astype(jnp.float32)
            out_ref[...] = (c1.astype(jnp.float32) * (2.0 ** (2 * h))
                            + mid * (2.0 ** h) + c0.astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=("h", "block_m", "block_n", "block_k", "combine_int32",
                     "interpret"),
)
def mm2_gemm_planes(
    a1: Array, a0: Array, b1: Array, b0: Array, *,
    h: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    combine_int32: bool = False,
    interpret: Optional[bool] = None,
) -> Array:
    """MM2 GEMM on pre-split s8 digit planes (see kmm_gemm for conventions)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = a1.shape
    _, n = b1.shape
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k, block_m, block_n, block_k))
    grid = (m // block_m, n // block_n, k // block_k)
    out_dtype = jnp.int32 if combine_int32 else jnp.float32
    kernel = functools.partial(
        _mm2_kernel, h=h, nk=grid[2], combine_int32=combine_int32)
    a_spec = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)] * 4,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a1, a0, b1, b0)
