"""FFIP — free-pipeline fast inner-product (paper's prior work [6], Table II).

FFIP halves multiplier count by computing, inside each PE,
``(a_even + b_odd) * (a_odd + b_even)`` — one multiply where a MAC array needs
two — and subtracting row-only/column-only correction sums.

Hardware-adaptation note (DESIGN.md §2/§8): FFIP's mechanism is a *PE-array*
trick — an adder placed before the multiplier inside every processing
element.  The TPU MXU is a fixed multiply-accumulate systolic array whose
operand paths cannot be pre-added across LHS/RHS, so FFIP has **no TPU kernel
analogue**; algebraically the decomposition collapses back to
``ae @ be + ao @ bo`` when executed on fixed matmul units (same multiply
count).  We therefore implement FFIP as (1) a literal reference used to
validate the algebra and (2) the efficiency/throughput model behind the
Table II reproduction — not as a Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ffip_gemm_literal(a: Array, b: Array) -> Array:
    """Literal FFIP evaluation (materializes (M, K/2, N); small shapes only).

    c_ij = sum_k (ae_ik + bo_kj)(ao_ik + be_kj) - sum_k ae_ik*ao_ik
           - sum_k be_kj*bo_kj
    """
    assert a.shape[1] % 2 == 0, "FFIP needs even K"
    ae, ao = a[:, 0::2].astype(jnp.int32), a[:, 1::2].astype(jnp.int32)
    be, bo = b[0::2, :].astype(jnp.int32), b[1::2, :].astype(jnp.int32)
    # (M, K/2, N): (ae + bo) and (ao + be) with broadcast over the other side.
    lhs = ae[:, :, None] + bo[None, :, :]
    rhs = ao[:, :, None] + be[None, :, :]
    prod = (lhs * rhs).sum(axis=1)
    a_corr = (ae * ao).sum(axis=1, keepdims=True)
    b_corr = (be * bo).sum(axis=0, keepdims=True)
    return prod - a_corr - b_corr


def ffip_mults(m: int, k: int, n: int) -> int:
    """Multiplications FFIP spends on an (M,K)x(K,N) GEMM: half the MACs plus
    the amortized row/col correction products."""
    return m * n * (k // 2) + m * (k // 2) + n * (k // 2)
