"""Pure-jnp oracles for the GEMM kernels.

``ref_int_gemm`` is the jit-able oracle (exact int32 dot within carrier
bounds); ``ref_int_gemm_i64`` is the out-of-jit numpy int64 oracle used by the
test suite to certify the jnp oracle itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

Array = jax.Array


def ref_int_gemm(a: Array, b: Array) -> Array:
    """Exact int32 GEMM oracle: (M, K) @ (K, N) with int32 accumulation."""
    return lax.dot_general(
        a.astype(jnp.int32), b.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def ref_int_gemm_i64(a, b) -> np.ndarray:
    """numpy int64 oracle — exact for all w <= 16 and any practical K."""
    return np.asarray(a, dtype=np.int64) @ np.asarray(b, dtype=np.int64)


def ref_digit_planes(x: Array, w: int):
    """The centered s8 digit planes used by the kernels (see kmm_gemm.py).

    Returns (hi, lo_centered, h, z) with x == (hi << h) + lo_centered + z
    elementwise, hi/lo_centered both in s8 range for w <= 16.
    """
    h = -(-w // 2)
    z = 1 << (h - 1)
    xi = x.astype(jnp.int32)
    hi = jnp.right_shift(xi, h)
    lo = jnp.bitwise_and(xi, (1 << h) - 1) - z
    return hi.astype(jnp.int8), lo.astype(jnp.int8), h, z


def ref_kmm2_planes(a1: Array, a0: Array, b1: Array, b0: Array, h: int,
                    combine_int32: bool = False) -> Array:
    """jnp mirror of the KMM2 kernel math on digit planes (no tiling)."""
    a1i, a0i = a1.astype(jnp.int32), a0.astype(jnp.int32)
    b1i, b0i = b1.astype(jnp.int32), b0.astype(jnp.int32)
    c1 = ref_int_gemm(a1i, b1i)
    cs = ref_int_gemm(a1i + a0i, b1i + b0i)
    c0 = ref_int_gemm(a0i, b0i)
    if combine_int32:
        return (c1 << (2 * h)) + ((cs - c1 - c0) << h) + c0
    c1f, c0f = c1.astype(jnp.float32), c0.astype(jnp.float32)
    mid = cs.astype(jnp.float32) - c1f - c0f
    return c1f * (2.0 ** (2 * h)) + mid * (2.0 ** h) + c0f


def ref_mm2_planes(a1: Array, a0: Array, b1: Array, b0: Array, h: int,
                   combine_int32: bool = False) -> Array:
    """jnp mirror of the MM2 kernel math on digit planes (no tiling)."""
    a1i, a0i = a1.astype(jnp.int32), a0.astype(jnp.int32)
    b1i, b0i = b1.astype(jnp.int32), b0.astype(jnp.int32)
    c1 = ref_int_gemm(a1i, b1i)
    c10 = ref_int_gemm(a1i, b0i)
    c01 = ref_int_gemm(a0i, b1i)
    c0 = ref_int_gemm(a0i, b0i)
    if combine_int32:
        return (c1 << (2 * h)) + ((c10 + c01) << h) + c0
    mid = c10.astype(jnp.float32) + c01.astype(jnp.float32)
    return (c1.astype(jnp.float32) * (2.0 ** (2 * h)) + mid * (2.0 ** h)
            + c0.astype(jnp.float32))
