"""Pallas TPU kernel: RWKV6 WKV recurrence with the matrix state in VMEM.

The roofline analysis (EXPERIMENTS.md §Roofline) shows rwkv6-3b train/prefill
is memory-dominated: the unfused HLO recurrence reads+writes the (B, H, D, D)
state from HBM every timestep (~9 state-sized tensors per step).  This kernel
keeps the state in a VMEM scratch accumulator across the whole sequence —
HBM traffic collapses to the r/k/v/w streams plus one state write per
(batch, head):

    traffic_unfused ~ S * 9 * D^2 * 4B        (per head)
    traffic_kernel  ~ S * 4 * D * 4B + D^2*4B

Grid: (B*H, S/chunk) with the sequence dim sequential ('arbitrary') so the
state scratch persists across chunks.  Inside a chunk, a fori_loop steps the
recurrence: S_t = diag(w_t) S_{t-1} + k_t^T v_t;  y_t = r_t (S_{t-1} + diag(u)
k_t^T v_t).  Validated against the pure-jnp oracle in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

Array = jax.Array


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_ref, *,
                chunk: int):
    sc = pl.program_id(1)

    @pl.when(sc == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0, :]                                      # (D,)

    def step(t, state):
        rt = r_ref[0, t, :]
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]
        wt = w_ref[0, t, :]
        kv = kt[:, None] * vt[None, :]                   # (D, D)
        yt = ((state + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        y_ref[0, t, :] = yt
        return wt[:, None] * state + kv

    state_ref[...] = jax.lax.fori_loop(0, chunk, step, state_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_apply(r: Array, k: Array, v: Array, w: Array, u: Array, *,
              chunk: int = 128, interpret: Optional[bool] = None) -> Array:
    """r/k/v/w: (BH, S, D) fp32 streams (flattened batch*heads);
    u: (BH, D) bonus. Returns y: (BH, S, D) fp32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bh, s, d = r.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    grid = (bh, s // chunk)
    spec = pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0))
    u_spec = pl.BlockSpec((1, d), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec, spec, spec, u_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)


def wkv_reference(r: Array, k: Array, v: Array, w: Array, u: Array) -> Array:
    """Pure-jnp oracle: sequential scan over timesteps."""
    def step(state, xs):
        rt, kt, vt, wt = xs                              # (BH, D)
        kv = kt[..., :, None] * vt[..., None, :]
        yt = jnp.einsum("bi,bij->bj", rt, state + u[..., None] * kv)
        return wt[..., :, None] * state + kv, yt

    bh, s, d = r.shape
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state0 = jnp.zeros((bh, d, d), jnp.float32)
    _, y = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(y, 0, 1)


def hbm_traffic_model(bh: int, s: int, d: int):
    """First-order HBM bytes: unfused HLO recurrence vs this kernel."""
    unfused = bh * s * 9 * d * d * 4.0
    kernel = bh * (s * 5 * d * 4.0 + d * d * 4.0)
    return {"unfused_bytes": unfused, "kernel_bytes": kernel,
            "reduction": unfused / kernel}
