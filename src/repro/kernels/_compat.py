"""Pallas API compatibility across jax versions."""
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes pltpu.CompilerParams as TPUCompilerParams
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
