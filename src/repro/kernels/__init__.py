"""Pallas TPU kernels (validated in interpret mode on CPU; see DESIGN.md §2).

fused_gemm — single-pass KMM2/MM1 GEMM: in-kernel digit split, zero-point
            correction and dequant epilogue in one pallas_call (the
            production pallas route, DESIGN.md §11; grouped MoE variant).
kmm_gemm  — KMM2 integer GEMM on pre-split planes: 3 digit-plane MXU
            passes + Algorithm-5 two-level accumulation (the paper's
            Fig. 8 architecture); staged fallback + fused-kernel oracle.
mm2_gemm  — conventional 4-pass baseline (Fig. 3).
mm1_gemm  — single-pass int8 baseline (Fig. 7).
wkv_gemm  — RWKV6 recurrence with state resident in VMEM.
ffip      — FFIP reference + why it has no MXU analogue.
ops       — dispatching wrapper (digit planes, zero-point correction).
ref       — pure-jnp oracles.
"""
from repro.kernels.ops import int_gemm, int_gemm_jit
