"""Sharding rules: logical-axis partitioning for params, caches and batches.

MaxText-style two-level mapping (DESIGN.md §4.1): each weight leaf gets
*logical* axes from its name (``wi -> ("embed", "mlp")``), and a rules table
maps logical axes onto mesh axes (``"mlp" -> "model"``, ``"embed" ->
"data"`` i.e. FSDP).  A mesh axis is only assigned when the dimension is
divisible by it and the axis is not already used by the same spec, so the
rules degrade gracefully on small smoke configs and 1-device meshes.

Block params carry a leading ``n_periods`` stacking dim (and MoE weights an
expert dim); rules apply to the trailing matmul dims, the expert dim rides
the ``model`` axis (expert parallelism), and stacking dims stay replicated.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.interpreters import pxla
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any

# Mesh axes that carry the (global) batch dimension, in mesh order.
BATCH_AXES = ("pod", "data")

# Logical axis -> mesh axes it may map onto (first fit wins).
LOGICAL_RULES: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("batch", ("pod", "data")),
    ("embed", ("data",)),        # FSDP: hidden dim sharded over data
    ("vocab", ("model",)),       # vocab-parallel embedding / head
    ("heads", ("model",)),       # tensor parallel: attention heads
    ("mlp", ("model",)),         # tensor parallel: FFN hidden
    ("inner", ("model",)),       # tensor parallel: SSM inner dim
    ("expert", ("model",)),      # expert parallelism
    ("stack", ()),               # n_periods scan stacking: replicated
)

# Weight-leaf name -> logical axes of the *trailing* dims.  ``None`` entries
# are replicated.  Names not listed fall back to ("embed", "heads") for
# trailing-2D leaves (row FSDP, column TP) and full replication otherwise.
PARAM_LOGICAL_AXES = {
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "wq": ("embed", "heads"),
    "wk": ("embed", "heads"),
    "wv": ("embed", "heads"),
    "wg": ("embed", "mlp"),
    "wi": ("embed", "mlp"),
    "wr": ("embed", "heads"),
    "wo": ("heads", "embed"),      # output proj: row TP, column FSDP
    "out_proj": ("inner", "embed"),
    "in_proj": ("embed", "inner"),
    "x_proj": ("inner", None),
    "dt_proj": (None, "inner"),
    "w1": ("embed", "mlp"),
    "w2": ("embed", "embed"),
    "router": ("embed", None),
}

# Small / vector leaves that always stay replicated.
NEVER_SHARD = {
    "scale", "bias", "mix", "u", "w0", "a_log", "d_skip", "dt_bias",
    "conv_w", "conv_b", "w_lora_a", "w_lora_b",
}


def _key_name(entry) -> str:
    return str(getattr(entry, "key", getattr(entry, "name", entry)))


def _path_names(path) -> Tuple[str, ...]:
    return tuple(_key_name(k) for k in path)


def mesh_axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.axis_names else 1


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes carrying the batch dim, in mesh order."""
    return tuple(a for a in mesh.axis_names if a in BATCH_AXES)


def batch_spec(mesh: Mesh) -> P:
    """PartitionSpec for a batch-leading array: dim 0 over all data axes.

    Returns an empty spec (``len() == 0``) when the mesh has no data axes,
    so callers can fall back to replication.
    """
    axes = data_axes(mesh)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def _mesh_axes_for(logical: Optional[str], dim: int, mesh: Mesh,
                   used: set) -> Optional[str]:
    """First mesh axis for ``logical`` that divides ``dim`` and is unused."""
    if logical is None:
        return None
    for name, axes in LOGICAL_RULES:
        if name != logical:
            continue
        for ax in axes:
            size = mesh_axis_size(mesh, ax)
            if size > 1 and dim % size == 0 and ax not in used:
                used.add(ax)
                return ax
        return None
    return None


def leaf_spec(path, leaf, mesh: Mesh) -> P:
    names = _path_names(path)
    name = names[-1]
    # Pre-quantized leaves ({"q": intN, "scale": ...}): the rule lives on
    # the parent weight name; scales are tiny and stay replicated.
    if name == "q" and len(names) >= 2:
        name = names[-2]
    shape = tuple(leaf.shape)
    ndim = len(shape)
    if name in NEVER_SHARD or ndim < 2:
        return P()
    logical = PARAM_LOGICAL_AXES.get(name)
    if logical is None:
        logical = ("embed", "heads")   # generic (K, N): row FSDP, col TP
    spec = [None] * ndim
    used: set = set()
    # An expert dim (MoE: the dim right before the matmul dims, under a
    # "moe" subtree) claims the model axis first — expert parallelism wins
    # over tensor parallelism inside an expert (see models/moe.py).
    if "moe" in names and ndim - len(logical) - 1 >= 0:
        e_idx = ndim - len(logical) - 1
        spec[e_idx] = _mesh_axes_for("expert", shape[e_idx], mesh, used)
    # Trailing dims get the logical rule (matmul layout).
    for off, lax_name in enumerate(reversed(logical)):
        dim_idx = ndim - 1 - off
        if dim_idx < 0:
            break
        spec[dim_idx] = _mesh_axes_for(lax_name, shape[dim_idx], mesh, used)
    return P(*spec)


def param_sharding(params: Params, mesh: Mesh) -> Params:
    """NamedSharding pytree for a param tree (concrete or ShapeDtypeStruct).

    2D weights are sharded on at least one mesh axis whenever divisibility
    permits: column/TP dims over ``model``, row dims over ``data`` (FSDP),
    vocab over ``model``.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, leaf_spec(path, leaf, mesh)),
        params)


# Cache-leaf name -> axis index (within the (n_periods, slot, ...) layout)
# that may shard over ``model``: attention kv-heads, rwkv heads, mamba inner.
CACHE_MODEL_AXES = {
    "k": 3,       # attn (n_periods, slot, Smax, K, D): kv-heads
    "v": 3,
    "wkv": 2,     # rwkv (n_periods, slot, H, D, D): heads
    "ssm": 2,     # mamba (n_periods, slot, d_inner, d_state): inner dim
    "conv": 3,    # mamba (n_periods, slot, cw-1, d_inner): inner dim
}


def cache_sharding(cache_shapes: Params, mesh: Mesh, *,
                   batch: int) -> Params:
    """NamedSharding pytree for a decode cache.

    Cache leaves are laid out ``(n_periods, slot, ...)``: axis 1 is the
    serve engine's decode-slot dimension (== the request batch), sharded
    directly over the mesh's data axes.  Per-leaf model
    parallelism: attention K/V shard their kv-heads dim, rwkv its head dim
    and mamba its inner dim over ``model`` (see ``CACHE_MODEL_AXES``), so
    decode stays head-/channel-parallel without resharding the weights.
    ``batch`` is the slot count (sanity-checked against axis 1).
    """
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh_axis_size(mesh, a)
    bentry = (daxes if len(daxes) > 1 else daxes[0]) if daxes else None
    msize = mesh_axis_size(mesh, "model")

    def leaf_sharding(path, leaf):
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        # axis 1 is the slot dim in the (n_periods, slot, ...) layout
        if len(shape) >= 2 and shape[1] == batch:
            if bentry is not None and dsize > 1 and shape[1] % dsize == 0:
                spec[1] = bentry
        name = _path_names(path)[-1]
        m_axis = CACHE_MODEL_AXES.get(name)
        if m_axis is not None and m_axis < len(shape) and msize > 1 \
                and shape[m_axis] % msize == 0:
            spec[m_axis] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf_sharding, cache_shapes)


def page_pool_sharding(pool_shapes: Params, mesh: Mesh) -> Params:
    """NamedSharding pytree for a paged serve-cache pool (serve/cache.py).

    Pool leaves are laid out ``(n_periods, page_or_state_row, ...)``: axis 1
    is the page (attn K/V pools) or state-row (recurrent pools) dimension,
    sharded over the mesh's data axes when divisible — the pool analogue of
    the slot dim in :func:`cache_sharding`.  The per-leaf model axes are
    unchanged from ``CACHE_MODEL_AXES``: swapping the slot dim for a
    page/state-row dim (and, for K/V, splitting Smax into (page_row, page))
    keeps the kv-head / rwkv-head / mamba-inner payload dims at the same
    indices, so the same table applies.
    """
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh_axis_size(mesh, a)
    bentry = (daxes if len(daxes) > 1 else daxes[0]) if daxes else None
    msize = mesh_axis_size(mesh, "model")

    def leaf_sharding(path, leaf):
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if len(shape) >= 2 and bentry is not None and dsize > 1 \
                and shape[1] % dsize == 0:
            spec[1] = bentry
        name = _path_names(path)[-1]
        m_axis = CACHE_MODEL_AXES.get(name)
        if m_axis is not None and m_axis < len(shape) and msize > 1 \
                and shape[m_axis] % msize == 0:
            spec[m_axis] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf_sharding, pool_shapes)


def _ambient_mesh() -> Optional[Mesh]:
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh


def constrain_batch_dim(x: jax.Array) -> jax.Array:
    """Keep an activation's leading (batch) dim sharded over the data axes.

    No-op outside a mesh context (single-device tests, plain eager calls),
    so model code can call it unconditionally.
    """
    if x is None:
        return x
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    axes = data_axes(mesh)
    if not axes or x.ndim == 0:
        return x
    spec = P(*((axes if len(axes) > 1 else axes[0],)
               + (None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
