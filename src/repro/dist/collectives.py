"""Communication-efficient collectives (DESIGN.md §4.2).

The paper's thesis — split wide integer arithmetic into narrow digit planes
and recombine cheaply — applies to the network as much as to the MXU.  These
primitives are the mesh-level analogue:

  * ``ef_compressed_psum``: int8-quantized all-reduce with error-feedback
    residual carried across steps (1-bit-Adam / PowerSGD lineage), so the
    wire moves 4x fewer bytes than f32 while the *accumulated* gradient
    stays unbiased.
  * ``ring_ag_matmul``: ring all-gather matmul via ``jax.lax.ppermute`` that
    overlaps each hop's transfer with the local shard GEMM; per-shard chunks
    can route through the paper's integer GEMM (``repro.kernels.ops
    .int_gemm``) when a bitwidth is supplied.
  * ``splitk_decode_attention``: decode attention over a model-axis-sharded
    KV cache, merged with a numerically-stable log-sum-exp across shards.

All functions are written for use inside ``shard_map`` (they speak
``axis_name``), and degrade to plain math on a 1-sized axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Error-feedback compressed all-reduce.
# ---------------------------------------------------------------------------


def ef_compress(x: Array, err: Array, *, bits: int = 8
                ) -> Tuple[Array, Array, Array]:
    """Quantize ``x + err`` to signed ``bits`` with a per-tensor scale.

    Returns ``(q, scale, new_err)`` with ``q * scale + new_err == x + err``
    exactly and ``|new_err| <= scale / 2`` (round-to-nearest): the residual
    the wire drops this round is re-injected next round, so compression
    error accumulates to at most one quantization step instead of growing
    with step count.
    """
    y = (x + err).astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(y)) / qmax
    scale = jnp.maximum(scale, jnp.float32(1e-30))
    q = jnp.clip(jnp.round(y / scale), -qmax, qmax)
    q = q.astype(jnp.int8 if bits <= 8 else jnp.int32)
    new_err = y - q.astype(jnp.float32) * scale
    return q, scale, new_err


def ef_compressed_psum(x: Array, err: Array, axis_name: str, *,
                       bits: int = 8) -> Tuple[Array, Array]:
    """All-reduce ``x`` over ``axis_name`` through int8 digit traffic.

    A single shared scale (one scalar ``pmax``) lets every shard quantize
    onto the same grid, so the all-reduce payload really is the integer
    plane — int8 digits accumulated in int32, as on the paper's hardware —
    plus one f32 scalar, not a dequantized f32 tensor.  Returns ``(total,
    new_err)``; callers thread ``new_err`` back in on the next step (error
    feedback), which bounds the accumulated compression error by one
    quantization step.
    """
    y = (x + err).astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jax.lax.pmax(jnp.max(jnp.abs(y)) / qmax, axis_name)
    scale = jnp.maximum(scale, jnp.float32(1e-30))
    q = jnp.clip(jnp.round(y / scale), -qmax, qmax)
    new_err = y - q * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype), new_err


# ---------------------------------------------------------------------------
# Ring all-gather matmul.
# ---------------------------------------------------------------------------


def _prep_rhs(w: Array, w_bits: Optional[int]):
    """Quantize the loop-invariant RHS once, outside the ring loop."""
    if w_bits is None:
        return w.astype(jnp.float32), None
    from repro.kernels.ops import quantize_symmetric

    return quantize_symmetric(w, w_bits)


def _shard_matmul(a: Array, qb: Array, sb, w_bits: Optional[int],
                  context=None) -> Array:
    """One shard-chunk GEMM; integer path when a bitwidth is supplied.

    ``context`` (an :class:`repro.core.context.ExecContext`) picks the
    backend for the chunk GEMMs.  Its mesh is stripped before the call: the
    ring already runs inside its own ``shard_map``, so each chunk is a
    single-shard GEMM — re-entering :mod:`repro.dist.shard_gemm` from here
    would nest shard_maps.
    """
    if w_bits is None:
        return jnp.dot(a.astype(jnp.float32), qb)
    from repro.kernels.ops import int_gemm, quantize_symmetric

    if context is not None and context.mesh is not None:
        context = context.replace(mesh=None)
    qa, sa = quantize_symmetric(a, w_bits)
    return int_gemm(qa, qb, w=w_bits, context=context) * sa * sb


def ring_ag_matmul(x_shard: Array, w: Array, axis_name: str, *,
                   w_bits: Optional[int] = None, context=None) -> Array:
    """Ring all-gather matmul: ``concat_shards(x) @ w`` without ever
    materializing the gathered LHS.

    ``x_shard``: this shard's rows of ``x`` (sharded over ``axis_name``);
    ``w``: replicated RHS.  Each of the N ring steps multiplies the block
    currently held against ``w`` while ``ppermute`` forwards it to the next
    neighbour, so the hop transfer overlaps the local GEMM (the classic
    collective-matmul overlap).  With ``w_bits`` set, each per-shard chunk
    routes through the paper's integer GEMM, on the backend picked by
    ``context`` (chunks always run single-shard — see ``_shard_matmul``).

    Returns the full ``(rows_total, n)`` product, replicated on every shard.
    """
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    rows = x_shard.shape[0]
    out_dtype = jnp.promote_types(x_shard.dtype, w.dtype)
    out = jnp.zeros((n * rows, w.shape[1]), jnp.float32)
    perm = [(j, (j + 1) % n) for j in range(n)]
    qb, sb = _prep_rhs(w, w_bits)
    block = x_shard
    for i in range(n):
        # The block in hand originated on shard (idx - i) mod n: its product
        # lands at that shard's row offset in the gathered output.
        src = jax.lax.rem(idx - i + n, n)
        part = _shard_matmul(block, qb, sb, w_bits, context=context)
        out = jax.lax.dynamic_update_slice(out, part, (src * rows, 0))
        if i + 1 < n:
            block = jax.lax.ppermute(block, axis_name, perm)
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# Split-K decode attention.
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def splitk_decode_attention(q: Array, k: Array, v: Array, valid: Array,
                            axis_name: str) -> Array:
    """One-token decode attention with K/V sharded over ``axis_name``.

    ``q``: (B, H, D) replicated; ``k``/``v``: (B, S_local, KH, D) — the
    local sequence slice of the cache; ``valid``: (B, S_local) bool mask for
    filled cache slots.  Each shard computes its partial softmax in the
    flash-attention (m, l, o) form; shards merge with a log-sum-exp that is
    exact and stable regardless of how the max is distributed:

        m* = pmax(m);  l* = psum(l * e^{m - m*});  o* = psum(o * e^{m - m*})

    Returns (B, H, D), replicated.  GQA is supported via KH <= H with
    H % KH == 0.
    """
    b, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qv = q.reshape(b, kh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qv, kf) * (d ** -0.5)
    scores = jnp.where(valid[:, None, None, :], scores, _NEG_INF)
    m_local = scores.max(axis=-1)                                # (B,KH,G)
    # m_local is floored at _NEG_INF (finite) by the mask above, so the
    # rescale below never sees inf - inf even for fully-invalid shards.
    m_global = jax.lax.pmax(m_local, axis_name)
    p = jnp.exp(scores - m_global[..., None])                    # (B,KH,G,S)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l_local = p.sum(axis=-1)
    o_local = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    l_tot = jax.lax.psum(l_local, axis_name)
    o_tot = jax.lax.psum(o_local, axis_name)
    out = o_tot / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(b, h, d).astype(q.dtype)
