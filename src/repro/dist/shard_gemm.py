"""Shard-mapped execution of the Pallas integer-GEMM kernels (DESIGN.md §12).

The fused single-pass kernel (kernels/fused_gemm.py) is not
GSPMD-partitionable — XLA cannot slice through a ``pallas_call`` — so under a
mesh every quantized GEMM used to fall back to plain dot_generals.  This
module closes that gap: the GEMM runs under
``jax.experimental.shard_map.shard_map`` with each shard executing the
*unmodified* kernel on its local block.

Layout (capability negotiation, :func:`negotiate`):

  * M (tokens / decode slots) shards over the data axes — the same axes the
    serve cache and batch ride (dist/sharding.py);
  * N (output channels) shards over the ``model`` axis — matching the
    column-TP weight rules (``wi -> ("embed", "mlp")``);
  * K is REPLICATED.  Every output element then sees the identical full-K
    digit arithmetic (same padded K, same zero-point correction, same fp32
    rounding) as the unsharded kernel, so sharded == unsharded **bit-exact**
    — the per-shard digit accumulators live entirely inside each shard's
    kernel launch and the zero-point correction runs per-shard *before* any
    collective, which is what keeps the contract exact.

An explicit K-sharded spec (``GemmShardSpec(k_axes=...)``) is also executed
— each shard's int32 partial product is ``psum``-combined — but only for
exact-int plans, where integer partial sums equal the true product;
:func:`negotiate` never proposes it (fp32-combine partials would change
rounding; see ``numerics_fingerprint``).

Fallback contract: when no mesh axis divides the GEMM (or the *local* K
fails the kernel's ``max_exact_k`` / digit-accumulator / VMEM bounds), the
caller downgrades that GEMM to the XLA backend with a logged reason —
capability negotiation, not a hard error (the old ``serve/engine.py``
mesh-rejection is gone).
"""
from __future__ import annotations

import logging
from dataclasses import replace
from typing import Optional, Tuple

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.dispatch import ExecPlan, GemmShardSpec
from repro.dist import sharding as dist_sharding
from repro.obs import metrics as obs_metrics

Array = jax.Array
Shape = Tuple[int, int, int]

log = logging.getLogger("repro.dist")

# One fallback log line per (shape, w, reason): negotiation runs at trace
# time inside jit caches, but also once per eager call — don't spam.
_LOGGED_FALLBACKS = set()

# Every fallback occurrence is COUNTED per (shape, w, reason) even though
# only the first is logged — a 64-slot serve run shows up as one log line
# and an honest count here.
_FALLBACKS = obs_metrics.counter(
    "repro_shard_gemm_fallback_total",
    "shard-mapped pallas GEMMs downgraded to XLA, by shape/w/reason",
    labels=("shape", "w", "reason"))


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= dist_sharding.mesh_axis_size(mesh, a)
    return size


def _axis_entry(axes: Tuple[str, ...]):
    """PartitionSpec entry for a dim sharded over ``axes`` (None if empty)."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def local_shape(shape: Shape, spec: GemmShardSpec, mesh: Mesh) -> Shape:
    """Per-shard (M, K, N) under ``spec`` on ``mesh``."""
    M, K, N = shape
    return (M // _axis_size(mesh, spec.m_axes),
            K // _axis_size(mesh, spec.k_axes),
            N // _axis_size(mesh, spec.n_axes))


def negotiate(shape: Shape, mesh: Optional[Mesh], *,
              n_experts: Optional[int] = None
              ) -> Tuple[Optional[GemmShardSpec], str]:
    """Pick mesh axes for an (M, K, N) GEMM, or explain why none fit.

    Returns ``(spec, reason)``: a usable :class:`GemmShardSpec` with
    ``reason == ""``, or ``(None, reason)`` when the mesh cannot tile this
    GEMM and the caller should fall back to XLA.  K is always replicated
    (bit-identity; see module docstring).  For grouped expert GEMMs
    (``n_experts``) the expert dim takes the model axis (expert parallelism,
    matching dist/sharding.py's MoE rule) and M/N stay local per expert.
    """
    if mesh is None or mesh.empty:
        return None, "no mesh"
    M, K, N = shape
    daxes = dist_sharding.data_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    msize = dist_sharding.mesh_axis_size(mesh, "model")
    if n_experts is not None:
        if msize > 1 and n_experts % msize == 0:
            return GemmShardSpec(e_axes=("model",)), ""
        return None, (f"expert dim {n_experts} not divisible by model "
                      f"axis ({msize})")
    m_axes = daxes if dsize > 1 and M % dsize == 0 else ()
    n_axes = ("model",) if msize > 1 and N % msize == 0 else ()
    if not m_axes and not n_axes:
        return None, (f"no mesh axis tiles ({M}, {K}, {N}): "
                      f"M={M} % data({dsize}) and N={N} % model({msize}) "
                      f"both nonzero")
    return GemmShardSpec(m_axes=m_axes, n_axes=n_axes), ""


def log_fallback(shape: Shape, w: int, reason: str) -> None:
    """Record a capability-negotiation XLA downgrade.

    Deduplication is explicit and applies to the LOG LINE only (once per
    unique (shape, w, reason) key); the metrics counter sees every
    occurrence, so fallback volume stays observable without log flood.
    """
    _FALLBACKS.inc("x".join(str(d) for d in shape), w, reason)
    key = (shape, w, reason)
    if key in _LOGGED_FALLBACKS:
        return
    _LOGGED_FALLBACKS.add(key)
    log.info("pallas GEMM %s (w=%d) under mesh falls back to XLA: %s",
             shape, w, reason)


# ---------------------------------------------------------------------------
# Shard-mapped wrappers.
# ---------------------------------------------------------------------------


def shard_dense_gemm(fn, mesh: Mesh, spec: GemmShardSpec):
    """shard_map a local ``(qx, qw, sx, sw) -> out`` dense GEMM over the mesh.

    ``qx``: (M, K); ``qw``: (K, N); ``sx``: (M, 1); ``sw``: (1, N); the
    returned callable takes the global operands and computes the global
    (M, N) output with each shard running ``fn`` on its local block.  K must
    be replicated in ``spec`` (fp32 bit-identity; use
    :func:`sharded_run_plan` for exact-int split-K).
    """
    if spec.k_axes:
        raise ValueError("dense dequant GEMM requires replicated K "
                         "(fp32 bit-identity); got k_axes=%r" % (spec.k_axes,))
    ms, ns = _axis_entry(spec.m_axes), _axis_entry(spec.n_axes)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(ms, None), P(None, ns), P(ms, None), P(None, ns)),
        out_specs=P(ms, ns), check_rep=False)


def shard_grouped_gemm(fn, mesh: Mesh, spec: GemmShardSpec,
                       counts: Optional[Array] = None):
    """shard_map a local ``(qx, qw, sx, sw[, counts]) -> out`` grouped GEMM.

    Operands are (E, C, K) / (E, K, N) / (E, C, 1) / (E, 1, N); the expert
    dim shards over ``spec.e_axes`` so each shard launches the grouped
    kernel over its local experts.  When ``counts`` (E, S) is given the
    ragged per-expert row counts shard over the same expert axis and are
    appended as a fifth operand — each shard sees exactly its local
    experts' live counts, so sharded ragged masking equals unsharded.
    The returned callable still takes ``(qx, qw, sx, sw)``; ``counts`` is
    closed over here.
    """
    es = _axis_entry(spec.e_axes)
    in_specs = [P(es, None, None)] * 4
    if counts is not None:
        in_specs.append(P(es, None))
    f = shard_map(
        fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=P(es, None, None), check_rep=False)
    if counts is None:
        return f
    return lambda qx, qw, sx, sw: f(qx, qw, sx, sw, counts)


def sharded_run_plan(a: Array, b: Array, *, plan: ExecPlan, mesh: Mesh,
                     interpret: Optional[bool] = None,
                     use_ref_kernels: bool = False) -> Array:
    """Shard-mapped :func:`repro.kernels.ops.run_plan` on (M, K) x (K, N).

    Uses ``plan.shard`` when set, else negotiates M/N axes.  Covers both the
    fused kernel and the staged Pallas fallback variants — whatever the plan
    routes to runs per-shard.  K-sharded specs are executed as int32 partial
    products ``psum``-combined over the K axes (exact-int plans only: the
    integer partials sum to the true product, so this composes the paper's
    kernel with the mesh collectives without moving a bit).
    """
    from repro.kernels import ops   # lazy: ops -> dispatch -> (tune) cycle

    spec = plan.shard
    if spec is None:
        spec, reason = negotiate((a.shape[0], a.shape[1], b.shape[1]), mesh)
        if spec is None:
            raise ValueError(f"cannot shard GEMM on mesh {mesh}: {reason}")
    local_plan = replace(plan, shard=None)
    if spec.k_axes and not local_plan.is_exact_int:
        raise ValueError(
            "K-sharded execution is exact-int only (fp32 partial sums "
            f"change rounding); plan {local_plan.variant!r} is fp32-combine")
    ms, ns, ks = (_axis_entry(spec.m_axes), _axis_entry(spec.n_axes),
                  _axis_entry(spec.k_axes))

    def local_fn(al, bl):
        out = ops.run_plan(al, bl, plan=local_plan, interpret=interpret,
                           use_ref_kernels=use_ref_kernels)
        if spec.k_axes:
            out = jax.lax.psum(out, spec.k_axes)
        return out

    f = shard_map(local_fn, mesh=mesh,
                  in_specs=(P(ms, ks), P(ks, ns)),
                  out_specs=P(ms, ns), check_rep=False)
    return f(a, b)


def plan_local_bounds_ok(plan: ExecPlan, lshape: Shape, w: int,
                         m: int) -> Tuple[bool, str]:
    """Check the kernel's correctness bounds on the per-shard LOCAL shape.

    Mirrors the unsharded checks in quant/qmatmul._fused_pallas, evaluated
    on the local K (identical here since negotiation replicates K, but the
    seam is explicit so K-sharded callers and future layouts stay honest) —
    plus the per-shard VMEM accounting from :mod:`repro.tune.space`.
    """
    from repro.core.kmm import max_exact_k
    from repro.core.strassen import STRASSEN_VARIANTS
    from repro.tune import space as tune_space

    _, k_local, _ = lshape
    if plan.variant in STRASSEN_VARIANTS:
        # Strassen's pre-adds and per-product accumulation must stay exact
        # on the shard's LOCAL block: re-run the full composed-bound
        # validation (tile split, (w+1)-bit sub-plan windows, sub tile
        # sanity and VMEM on the local half dims) rather than mirroring
        # its pieces here.
        reason = tune_space.validate(plan, lshape)
        if reason is not None:
            return False, f"strassen bounds on local shape {lshape}: {reason}"
        return True, ""
    if plan.is_exact_int and max_exact_k(w) < k_local:
        return False, (f"local K={k_local} > max_exact_k({w})="
                       f"{max_exact_k(w)}")
    kp = -(-k_local // plan.block_k) * plan.block_k
    bound = tune_space.plan_accum_k_bound(plan)
    if bound is not None and kp > bound:
        return False, (f"local padded K={kp} > accum bound {bound} for "
                       f"{plan.variant!r} depth={plan.depth} (w={w})")
    vmem = tune_space.vmem_footprint(plan)
    if vmem > tune_space.VMEM_BUDGET:
        return False, (f"per-shard VMEM footprint {vmem} > "
                       f"{tune_space.VMEM_BUDGET}")
    return True, ""
