"""Distributed execution: sharding rules and quantized collectives.

``repro.dist.sharding`` owns the logical-axis partitioning rules (MaxText
style) consumed by the train loop, step builders, and serve engine;
``repro.dist.collectives`` provides the communication-efficient primitives
(error-feedback int8 all-reduce, ring all-gather matmul, split-K decode
attention) that compose the paper's low-bit arithmetic with mesh
parallelism.  See DESIGN.md §4.
"""
from repro.dist import collectives, sharding  # noqa: F401
