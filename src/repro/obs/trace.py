"""Host-side structured span tracer with Chrome-trace / Perfetto export.

Spans are plain host-Python timing records around host-side control flow:
per-request lifetimes and per-engine-step phases in the serve engine, and
per-plan spans around ``run_plan``.  Nothing here touches jax — opening a
span inside a jitted function's *trace* records the (one-time) trace cost,
never a per-call device sync, and with tracing disabled (the default) a
``span(...)`` call returns a shared null singleton: no allocation, no
contextvar write, no clock read.  Enabling tracing therefore cannot change
any computed value (pinned by the serve token-identity test).

Export is the Chrome trace-event JSON format (``chrome://tracing`` /
Perfetto ``ui.perfetto.dev``): synchronous spans as complete events
(``ph: "X"``, microsecond ``ts``/``dur``), request lifetimes as async
begin/end pairs (``ph: "b"``/``"e"`` with an ``id``) so overlapping
requests render as separate tracks.  Nesting depth flows through a
contextvar, so spans opened across threads don't corrupt each other's
parent chain.
"""
from __future__ import annotations

import contextvars
import json
import os
import threading
import time
from typing import Dict, List, Optional

__all__ = ["enable", "disable", "enabled", "span", "instant",
           "begin_async", "end_async", "events", "clear", "chrome_trace",
           "export_chrome"]

_lock = threading.Lock()
_enabled = False
_events: List[dict] = []
# Monotonic epoch for the whole process: Chrome-trace ts values are relative
# microseconds, so one shared origin keeps every span on one timeline.
_EPOCH_NS = time.perf_counter_ns()

_span_path: contextvars.ContextVar = contextvars.ContextVar(
    "obs_span_path", default=())


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def _now_us() -> float:
    return (time.perf_counter_ns() - _EPOCH_NS) / 1e3


class _NullSpan:
    """Shared no-op span: the entire disabled-path cost of ``with span(...)``
    is one flag test plus entering/exiting this singleton."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "args", "_t0", "_token")

    def __init__(self, name: str, args: Dict[str, object]):
        self.name = name
        self.args = args
        self._t0 = 0.0
        self._token = None

    def __enter__(self):
        path = _span_path.get()
        self.args["depth"] = len(path)
        if path:
            self.args["parent"] = path[-1]
        self._token = _span_path.set(path + (self.name,))
        self._t0 = _now_us()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = _now_us()
        _span_path.reset(self._token)
        event = {
            "name": self.name,
            "ph": "X",
            "ts": self._t0,
            "dur": t1 - self._t0,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "cat": "repro",
            "args": self.args,
        }
        with _lock:
            _events.append(event)
        return False

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (e.g. chosen lane width)."""
        self.args.update(attrs)


def span(name: str, **attrs):
    """Context manager timing a host-side region.

    ``with trace.span("decode_step", step=i) as sp: ... sp.set(lanes=4)``
    """
    if not _enabled:
        return _NULL_SPAN
    return _Span(name, dict(attrs))


def instant(name: str, **attrs) -> None:
    """Zero-duration marker event (e.g. request finished, fallback taken)."""
    if not _enabled:
        return
    event = {"name": name, "ph": "i", "ts": _now_us(), "pid": os.getpid(),
             "tid": threading.get_ident(), "s": "t", "cat": "repro",
             "args": dict(attrs)}
    with _lock:
        _events.append(event)


def begin_async(name: str, async_id, **attrs) -> None:
    """Open an async span (request lifetime) — pairs with :func:`end_async`
    by (name, id); overlapping ids render as parallel tracks."""
    if not _enabled:
        return
    event = {"name": name, "ph": "b", "id": str(async_id), "ts": _now_us(),
             "pid": os.getpid(), "tid": threading.get_ident(),
             "cat": "repro", "args": dict(attrs)}
    with _lock:
        _events.append(event)


def end_async(name: str, async_id, **attrs) -> None:
    if not _enabled:
        return
    event = {"name": name, "ph": "e", "id": str(async_id), "ts": _now_us(),
             "pid": os.getpid(), "tid": threading.get_ident(),
             "cat": "repro", "args": dict(attrs)}
    with _lock:
        _events.append(event)


def events() -> List[dict]:
    with _lock:
        return list(_events)


def clear() -> None:
    with _lock:
        _events.clear()


def chrome_trace() -> Dict[str, list]:
    """The buffered events as a Chrome trace-event JSON object."""
    return {"traceEvents": events(), "displayTimeUnit": "ms"}


def export_chrome(path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(), f)
        f.write("\n")
