"""Measured memory-traffic accounting for the GEMM execution paths.

The paper's fused-kernel claim is a *traffic* argument — one HBM round trip
instead of the staged pipeline's ~6 passes — but wall time on an interpret-
mode CPU container only weakly reflects traffic.  This module measures
bytes-accessed directly from the compiler: each path is lowered through the
production :func:`repro.kernels.ops.run_plan_jit` seam and
``.lower(...).compile().cost_analysis()`` reports the compiled program's
``bytes accessed`` and ``flops`` (the same per-device numbers the dry-run
roofline uses, verified against a hand-computed matmul in tests).  When
``cost_analysis`` is unavailable the HLO text is parsed instead
(:func:`repro.launch.hlo_stats.parse_costs`, trip-count aware) and the row
records which method produced it.

Measured bytes are compared against the *analytic plane-traffic model* —
the same asymmetry :func:`repro.tune.space.cost_prior` prices when ranking
candidates, expressed in bytes:

  * ``fused``:  no digit planes in HBM; each operand tile's raw carrier
    (int8 when ``w <= m``, int16 above) is re-read once per reuse across
    the other grid axis, plus one fp32 output write.
  * ``staged``: plane build reads the int32 operands, writes 4 s8 digit
    planes, the kernel re-reads the planes per grid reuse, the zero-point
    correction re-reads both operands, and the core + correction +
    combine account ~3 fp32-output-sized passes.
  * ``xla``:    one pass over the operands and the output (the ideal
    single-dot floor; the XLA digit recursion's real traffic sits above
    it by a shape-independent factor).
  * ``strassen_kmm2`` / ``strassen_xla``: one tile-level Strassen split
    (core/strassen.py) — 7 half-shape sub-GEMMs at w+1 through the fused
    kernel / the XLA digit recursion, plus the tile-add plane traffic of
    the 10 pre-adds and the 8-term output combine.

Interpret-mode caveat (DESIGN.md §14): on this container the Pallas paths
run under the interpreter, which inflates absolute measured bytes by a
large but *per-path stable* factor.  The committed checks are therefore
structural — fused must measure below staged at every shape, and each
path's measured/analytic ratio must be consistent across shapes — rather
than a tight absolute tolerance; on a real TPU the same harness tightens
naturally because the ratios approach 1.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

Shape = Tuple[int, int, int]            # (M, K, N)

# The tuned deep-K bench geometry (benchmarks/bench_walltime.FUSED_SHAPES):
# ((M, K, N), block_k) at w=12, bm = bn = 128.
DEFAULT_SHAPES: Tuple[Tuple[Shape, int], ...] = (
    ((128, 4096, 128), 1024), ((128, 8192, 128), 2048))
SMOKE_SHAPES: Tuple[Tuple[Shape, int], ...] = (
    ((64, 256, 64), 64), ((64, 512, 64), 128))
DEFAULT_W = 12

# Per-row sanity window on measured/analytic: wide enough for the
# interpreter's stable inflation, tight enough that a dropped term or a
# double-counted pass (2x-16x swings) still trips it.
RATIO_WINDOW = (0.25, 32.0)
# Cross-shape consistency bound per path: max/min ratio over the swept
# shapes (a real traffic regression scales with shape; inflation doesn't).
CONSISTENCY_MAX = 2.0

TRAFFIC_KINDS = ("fused", "staged", "xla")

# The PR-9 kernel windows, each measured at a representative width:
# fused_mm2 vs the staged MM2 pipeline at w = 15 (the 2m-1 boundary),
# fused depth-2 (kmm4) vs staged kmm2-depth-2 at w = 20, and the ragged
# grouped expert launch at the default width.  Each (fused, staged) pair
# shares a width so the bytes ratio is apples-to-apples.
EXTENDED_KINDS: Tuple[Tuple[str, int], ...] = (
    ("fused_mm2", 15), ("staged_mm2", 15),
    ("fused_d2", 20), ("staged_d2", 20))
FUSED_PAIRS = (("fused", "staged"), ("fused_mm2", "staged_mm2"),
               ("fused_d2", "staged_d2"))
# Tile-level Strassen composition (core/strassen.py): both variants at
# w = 9, where the tuned flagship shape (256, 4096, 256) sits exactly at
# the composed K bound 2**(30 - 2w) = 4096 and each fused sub-GEMM
# inherits the full launch's 128x128x2048 tile geometry.
# ((128, 8192, 128), *) is deliberately absent: its K exceeds the bound.
STRASSEN_W = 9
STRASSEN_SHAPES: Tuple[Tuple[Shape, int], ...] = (
    ((128, 4096, 128), 2048), ((256, 4096, 256), 2048))
STRASSEN_KINDS = ("strassen_kmm2", "strassen_xla")
# The committed strassen pairwise claim is on ANALYTIC bytes: the two
# variants lower through different backends (Pallas subs vs XLA digit
# recursion), and interpret-mode inflation is per-backend (~28x Pallas vs
# ~11x XLA here), so a cross-backend measured comparison reflects the
# emulator, not traffic.  FUSED_PAIRS never hits this (always
# pallas-vs-pallas); the measured ratio is still recorded informationally
# and each row's measured/analytic stays window- and consistency-gated.
ANALYTIC_PAIRS = (("strassen_kmm2", "strassen_xla"),)
GROUPED_W = 12
GROUPED_EXPERTS = 4

_FUSED_KINDS = ("fused", "fused_mm2", "fused_d2")
_STAGED_KINDS = ("staged", "staged_mm2", "staged_d2")


def _pad(dim: int, block: int) -> int:
    return -(-dim // block) * block


def _carrier_bytes(w: int, m: int) -> int:
    """Per-element bytes of the fused kernel's raw operand carrier."""
    return 1 if w <= m else (2 if w <= 16 else 4)


def analytic_bytes(kind: str, shape: Shape, *, w: int = DEFAULT_W,
                   m: int = 8, tiles: Tuple[int, int, int] = None,
                   n_experts: int = 1) -> float:
    """Analytic HBM bytes of one GEMM path (the cost_prior traffic terms,
    priced in bytes).  ``tiles`` = (bm, bn, bk); required for the Pallas
    paths (grid reuse factors), ignored for ``xla``.  ``grouped`` prices
    ``n_experts`` independent fused launches plus the ragged counts read."""
    M, K, N = shape
    if kind == "xla":
        return 4.0 * (M * K + K * N) + 4.0 * M * N
    if kind in STRASSEN_KINDS:
        # One tile-split level: 7 sub-GEMMs on the (M/2, K/2, N/2)
        # quadrants at w + 1, plus the tile-add planes Strassen adds on
        # top — 10 operand pre-adds each read two int32 quadrant planes
        # and write one (15 element-passes over the operand quadrants),
        # and the 8-term output combine reads 7 int32 products and writes
        # 4 quadrants (11 passes of M/2 x N/2).
        Ms, Ks, Ns = -(-M // 2), -(-K // 2), -(-N // 2)
        adds = 60.0 * (Ms * Ks + Ks * Ns) + 44.0 * Ms * Ns
        if kind == "strassen_kmm2":
            per = analytic_bytes("fused", (Ms, Ks, Ns), w=w + 1, m=m,
                                 tiles=tiles)
        else:
            # XLA digit-recursion sub-GEMM: plane build + three digit
            # dots + zero-point sums put ~5 int32 passes over each
            # operand and ~4 over the output — well above the single-dot
            # "xla" floor, which would misprice the comparison.
            per = 20.0 * (Ms * Ks + Ks * Ns) + 16.0 * Ms * Ns
        return 7.0 * per + adds
    bm, bn, bk = tiles
    Mp, Np, Kp = _pad(M, bm), _pad(N, bn), _pad(K, bk)
    ra, rb = Np // bn, Mp // bm         # reuse of A-tiles / B-tiles
    if kind in _FUSED_KINDS:
        opd = _carrier_bytes(w, m)
        return opd * (Mp * Kp * ra + Kp * Np * rb) + 4.0 * Mp * Np
    if kind == "grouped":
        opd = _carrier_bytes(w, m)
        per = opd * (Mp * Kp * ra + Kp * Np * rb) + 4.0 * Mp * Np
        return n_experts * per + 4.0 * n_experts  # + (E, S) int32 counts
    if kind in _STAGED_KINDS:
        # Depth 2 stages two levels of digit planes (level-1 split feeds
        # three level-2 plane GEMM branches): scale the plane write/read
        # terms by digits // 2, the same asymmetry cost_prior prices.
        lv = 2.0 if kind == "staged_d2" else 1.0
        return (4.0 * (M * K + K * N)           # plane build reads (int32)
                + lv * 2.0 * (Mp * Kp + Kp * Np)  # digit-plane writes
                + lv * 2.0 * (Mp * Kp * ra + Kp * Np * rb)  # plane reads
                + 4.0 * (M * K + K * N)         # correction rowsum/colsum
                + 3.0 * 4.0 * Mp * Np)          # core + corr + combine out
    raise ValueError(f"unknown traffic kind {kind!r}")


def _extract_costs(cost) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` output (dict on some jax
    versions, list-of-dicts per computation on others)."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    if not isinstance(cost, dict):
        return {}
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0))}


def measure_costs(lowered) -> Dict[str, float]:
    """``{"flops", "bytes", "method"}`` of one lowered jax computation.

    Primary source is XLA's ``cost_analysis``; when it is missing or
    reports zero bytes, the HLO text is parsed instead (trip-count aware —
    XLA's analysis counts while bodies once).
    """
    compiled = lowered.compile()
    out: Dict[str, float] = {"flops": 0.0, "bytes": 0.0, "method": "none"}
    try:
        got = _extract_costs(compiled.cost_analysis())
    except Exception:
        got = {}
    if got.get("bytes"):
        got["method"] = "cost_analysis"
        return got
    try:
        from repro.launch.hlo_stats import parse_costs
        parsed = parse_costs(compiled.as_text())
        out = {"flops": float(parsed.get("flops", 0.0)),
               "bytes": float(parsed.get("bytes", 0.0)),
               "method": "hlo_text"}
    except Exception:
        pass
    if got:
        out["flops"] = out["flops"] or got.get("flops", 0.0)
    return out


def _plan_for(kind: str, w: int, m: int,
              tiles: Tuple[int, int, int]):
    from repro.core.dispatch import ExecPlan, analytic_plan
    bm, bn, bk = tiles
    kw = dict(backend="pallas", block_m=bm, block_n=bn, block_k=bk)
    if kind == "fused":
        return ExecPlan("fused", w, m, combine_int32=w <= m,
                        depth=0 if w <= m else 1, **kw)
    if kind == "fused_mm2":
        return ExecPlan("fused_mm2", w, m, depth=1, **kw)
    if kind == "fused_d2":
        return ExecPlan("fused", w, m, depth=2, **kw)
    if kind == "staged":
        return ExecPlan("kmm2", w, m, depth=1, **kw)
    if kind == "staged_mm2":
        return ExecPlan("mm2", w, m, depth=1, **kw)
    if kind == "staged_d2":
        return ExecPlan("kmm2", w, m, depth=2, **kw)
    if kind == "strassen_kmm2":
        return ExecPlan("strassen+kmm2", w, m, combine_int32=True,
                        depth=1, **kw)
    if kind == "strassen_xla":
        return ExecPlan("strassen", w, m, backend="xla",
                        combine_int32=True, depth=1)
    if kind == "xla":
        return analytic_plan(w, m, backend="xla")
    raise ValueError(f"unknown traffic kind {kind!r}")


def measure_plan_bytes(plan, a, b, *,
                       interpret: Optional[bool] = None) -> float:
    """Compiled bytes-accessed of one ExecPlan on concrete operands (the
    tuner's per-candidate traffic column).  0.0 when no method works."""
    from repro.kernels import ops
    try:
        lowered = ops.run_plan_jit.lower(a, b, plan, interpret)
        return measure_costs(lowered)["bytes"]
    except Exception:
        return 0.0


def traffic_rows(shapes: Sequence[Tuple[Shape, int]] = DEFAULT_SHAPES,
                 *, w: int = DEFAULT_W, m: int = 8,
                 kinds: Sequence[str] = TRAFFIC_KINDS,
                 interpret: Optional[bool] = None) -> List[Dict]:
    """Measured-vs-analytic traffic rows for every path at every shape.

    One row per (kind, shape) with ``measured_bytes`` / ``analytic_bytes``
    / ``measured_over_analytic``, plus one ``<fused>_over_<staged>_bytes``
    row per shape for every measured (fused, staged) pair — the committed
    form of the paper's traffic claim, per kernel window.
    """
    from repro.kernels import ops
    from repro.tune.runner import make_operands

    rows: List[Dict] = []
    for (shape, bk) in shapes:
        M, K, N = shape
        tiles = (min(128, M), min(128, N), bk)
        tag = f"{M}x{K}x{N}"
        a, b = make_operands(shape, w)
        measured: Dict[str, float] = {}
        analytic: Dict[str, float] = {}
        for kind in kinds:
            plan = _plan_for(kind, w, m, tiles)
            try:
                lowered = ops.run_plan_jit.lower(a, b, plan, interpret)
                got = measure_costs(lowered)
            except Exception as e:
                rows.append({"bench": "roofline",
                             "name": f"roofline/traffic_{kind}_w{w}_{tag}",
                             "kind": kind, "shape": tag, "w": w,
                             "dominant": "ERROR",
                             "note": f"{type(e).__name__}: {e}"[:120]})
                continue
            ana = analytic_bytes(kind, shape, w=w, m=m, tiles=tiles)
            measured[kind] = got["bytes"]
            analytic[kind] = ana
            rows.append({
                "bench": "roofline",
                "name": f"roofline/traffic_{kind}_w{w}_{tag}",
                "kind": kind, "shape": tag, "w": w,
                "tiles": "x".join(str(t) for t in tiles),
                "measured_bytes": got["bytes"],
                "analytic_bytes": ana,
                "measured_over_analytic": round(got["bytes"] / ana, 4)
                if ana else 0.0,
                "flops": got["flops"],
                "method": got["method"],
            })
        for fk, sk in FUSED_PAIRS:
            if measured.get(fk) and measured.get(sk):
                suffix = "" if fk == "fused" else f"_w{w}"
                rows.append({
                    "bench": "roofline",
                    "name": (f"roofline/traffic_{fk}_over_{sk}_bytes"
                             f"{suffix}_{tag}"),
                    "shape": tag, "w": w,
                    "bytes_ratio": round(measured[fk] / measured[sk], 4),
                    "expect": "< 1.0 (single-pass kernel vs staged "
                              "pipeline)",
                })
        for fk, sk in ANALYTIC_PAIRS:
            if analytic.get(fk) and analytic.get(sk):
                row = {
                    "bench": "roofline",
                    "name": (f"roofline/traffic_{fk}_over_{sk}_bytes"
                             f"_w{w}_{tag}"),
                    "shape": tag, "w": w,
                    "analytic_bytes_ratio":
                        round(analytic[fk] / analytic[sk], 4),
                    "expect": "< 1.0 analytic (7 fused sub-GEMMs vs 7 XLA "
                              "digit-recursion sub-GEMMs; cross-backend "
                              "measured bytes reflect interpret-mode "
                              "inflation, see module docstring)",
                }
                if measured.get(fk) and measured.get(sk):
                    row["measured_bytes_ratio"] = round(
                        measured[fk] / measured[sk], 4)
                rows.append(row)
    return rows


def grouped_traffic_rows(shapes: Sequence[Tuple[Shape, int]]
                         = DEFAULT_SHAPES, *, w: int = GROUPED_W,
                         m: int = 8, n_experts: int = GROUPED_EXPERTS,
                         interpret: Optional[bool] = None) -> List[Dict]:
    """Measured traffic of the ragged grouped-expert fused launch.

    Lowered through :func:`repro.kernels.fused_gemm.fused_gemm_grouped`
    with a live (E, 1) counts operand — the serve MoE path's kernel —
    against the analytic model of ``n_experts`` dense fused launches.
    """
    import jax.numpy as jnp
    from repro.kernels.fused_gemm import fused_gemm_grouped
    from repro.tune.runner import make_operands

    rows: List[Dict] = []
    for (shape, bk) in shapes:
        M, K, N = shape
        tiles = (min(128, M), min(128, N), bk)
        tag = f"{n_experts}x{M}x{K}x{N}"
        a, b = make_operands(shape, w)
        ag = jnp.broadcast_to(a[None], (n_experts,) + a.shape)
        bg = jnp.broadcast_to(b[None], (n_experts,) + b.shape)
        counts = jnp.full((n_experts, 1), M, dtype=jnp.int32)
        try:
            lowered = fused_gemm_grouped.lower(
                ag, bg, counts=counts, w=w, m=m, seg=M,
                block_m=tiles[0], block_n=tiles[1], block_k=tiles[2],
                interpret=interpret)
            got = measure_costs(lowered)
        except Exception as e:
            rows.append({"bench": "roofline",
                         "name": f"roofline/traffic_grouped_w{w}_{tag}",
                         "kind": "grouped", "shape": tag, "w": w,
                         "dominant": "ERROR",
                         "note": f"{type(e).__name__}: {e}"[:120]})
            continue
        ana = analytic_bytes("grouped", shape, w=w, m=m, tiles=tiles,
                             n_experts=n_experts)
        rows.append({
            "bench": "roofline",
            "name": f"roofline/traffic_grouped_w{w}_{tag}",
            "kind": "grouped", "shape": tag, "w": w,
            "tiles": "x".join(str(t) for t in tiles),
            "measured_bytes": got["bytes"],
            "analytic_bytes": ana,
            "measured_over_analytic": round(got["bytes"] / ana, 4)
            if ana else 0.0,
            "flops": got["flops"],
            "method": got["method"],
        })
    return rows


def all_traffic_rows(shapes: Sequence[Tuple[Shape, int]] = DEFAULT_SHAPES,
                     *, m: int = 8,
                     interpret: Optional[bool] = None) -> List[Dict]:
    """Every committed traffic row: the original w=12 fused/staged/xla
    sweep plus the PR-9 windows (fused_mm2 at w=15, depth-2 at w=20, the
    ragged grouped launch) over the same shapes."""
    rows = traffic_rows(shapes, w=DEFAULT_W, m=m, interpret=interpret)
    by_w: Dict[int, List[str]] = {}
    for kind, kw in EXTENDED_KINDS:
        by_w.setdefault(kw, []).append(kind)
    for kw, kinds in sorted(by_w.items()):
        rows.extend(traffic_rows(shapes, w=kw, m=m, kinds=kinds,
                                 interpret=interpret))
    # Strassen rides its own shape list at the default sweep (its flagship
    # shape sits exactly at the composed K bound; the deep-K default shape
    # exceeds it), but follows the caller's shapes in smoke runs.
    s_shapes = STRASSEN_SHAPES if tuple(shapes) == DEFAULT_SHAPES else shapes
    rows.extend(traffic_rows(s_shapes, w=STRASSEN_W, m=m,
                             kinds=STRASSEN_KINDS, interpret=interpret))
    rows.extend(grouped_traffic_rows(shapes, m=m, interpret=interpret))
    return rows


def traffic_checks(rows: Sequence[Dict]) -> List[Tuple[str, bool, str]]:
    """Pass/fail verdicts over :func:`traffic_rows` output (see module
    docstring for why the checks are structural in interpret mode)."""
    checks: List[Tuple[str, bool, str]] = []
    measured = [r for r in rows if "measured_bytes" in r]
    errors = [r for r in rows if r.get("dominant") == "ERROR"]
    checks.append(("traffic harness produced measured rows",
                   bool(measured) and not errors,
                   f"{len(measured)} measured, {len(errors)} errors"))
    by_shape: Dict[str, Dict[str, float]] = {}
    by_kind: Dict[str, List[float]] = {}
    for r in measured:
        by_shape.setdefault(r["shape"], {})[r["kind"]] = r["measured_bytes"]
        by_kind.setdefault(r["kind"], []).append(r["measured_over_analytic"])
    for tag, kinds in sorted(by_shape.items()):
        for fk, sk in FUSED_PAIRS:
            if fk in kinds and sk in kinds:
                ratio = kinds[fk] / kinds[sk] if kinds[sk] else 0
                checks.append(
                    (f"{fk} measured bytes <= {sk} at {tag}",
                     0 < kinds[fk] <= kinds[sk],
                     f"{fk}/{sk} = {ratio:.3f}"))
    for r in rows:
        if "analytic_bytes_ratio" in r:
            checks.append(
                (f"analytic bytes ratio < 1.0 for "
                 f"{r['name'].rsplit('/', 1)[-1]}",
                 0 < r["analytic_bytes_ratio"] < 1.0,
                 f"ratio {r['analytic_bytes_ratio']}"))
    lo, hi = RATIO_WINDOW
    for r in measured:
        checks.append(
            (f"measured/analytic within [{lo}, {hi}] for "
             f"{r['kind']} at {r['shape']}",
             lo <= r["measured_over_analytic"] <= hi,
             f"ratio {r['measured_over_analytic']} ({r['method']})"))
    for kind, ratios in sorted(by_kind.items()):
        if len(ratios) > 1 and min(ratios) > 0:
            spread = max(ratios) / min(ratios)
            checks.append(
                (f"{kind} measured/analytic consistent across shapes "
                 f"(max/min <= {CONSISTENCY_MAX})",
                 spread <= CONSISTENCY_MAX, f"spread {spread:.3f}"))
    return checks
