"""repro.obs — unified observability: metrics, tracing, traffic (DESIGN.md §14).

Three host-side subsystems, all zero-overhead when disabled (the default):

  * :mod:`repro.obs.metrics` — process-global counter/gauge/histogram
    registry (JSON snapshot + Prometheus text export);
  * :mod:`repro.obs.trace`   — structured span tracer exporting
    Chrome-trace/Perfetto JSON;
  * :mod:`repro.obs.traffic` — measured memory-traffic accounting
    (compiler bytes-accessed vs the analytic plane-traffic model).

``enable_all()`` / ``disable_all()`` flip metrics and tracing together
(what ``launch/serve.py --metrics-out/--trace-out`` uses).  Instrumentation
never touches jax values — enabling it cannot move a bit of any computed
output.
"""
from repro.obs import metrics, trace, traffic

__all__ = ["metrics", "trace", "traffic", "enable_all", "disable_all"]


def enable_all() -> None:
    metrics.enable()
    trace.enable()


def disable_all() -> None:
    metrics.disable()
    trace.disable()
