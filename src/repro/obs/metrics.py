"""Process-global metrics registry: counters, gauges, histograms.

One registry for every signal the stack used to scatter across ad-hoc
``log.info`` calls and per-bench stat structs: shard_gemm's per-(shape, w,
reason) XLA-fallback counts, plan-selection counts keyed (variant, backend,
bucket), serve retrace/lane-width counters, the scheduler occupancy gauge and
the TTFT / decode-step-latency histograms.  Everything lands in one place
that can be snapshotted (:func:`snapshot` → JSON), scraped
(:func:`prometheus_text` → Prometheus exposition format) and regressed.

Contract — **zero overhead when disabled, host-side only**:

  * Every instrument mutation (``inc`` / ``set`` / ``observe``) checks one
    module-level boolean *before* touching any lock or dict.  With metrics
    disabled (the default) an instrumented call site costs a function call
    and a flag test — no dict churn, no allocation, no lock.
  * Instruments are only ever called from host Python with host values
    (trace-time plan selection, the serve engine's step loop, negotiation
    fallbacks).  Nothing here may be fed a traced ``jax.Array`` or called
    with values only known inside a jitted computation — instrumentation
    must never introduce a sync point or change a jit trace.  Enabling or
    disabling metrics therefore cannot move a bit of any computed output
    (pinned by ``tests/test_obs.py`` serve token-identity).

Instruments register lazily at module import of the instrumented code
(idempotent: re-registering the same name with the same kind/labels returns
the existing instrument; a conflicting re-registration raises).  Label
values are positional, matching the declared label names, and are
stringified.  All mutation is thread-safe (one registry lock) — the serve
engine and background threads may hit the same counter concurrently.
"""
from __future__ import annotations

import json
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["enable", "disable", "enabled", "counter", "gauge", "histogram",
           "get", "snapshot", "prometheus_text", "reset", "write_snapshot",
           "DEFAULT_BUCKETS"]

_lock = threading.RLock()
_enabled = False

# Latency-style default buckets (seconds): spans serve TTFT on smoke configs
# (~10ms) through queueing-dominated arrivals (seconds).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0)


def enable() -> None:
    """Turn instrument mutations on (process-global)."""
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


class _Metric:
    """Base: named instrument with fixed label names."""

    kind = "untyped"

    def __init__(self, name: str, help: str, label_names: Tuple[str, ...]):
        self.name = name
        self.help = help
        self.label_names = label_names
        self._data: Dict[Tuple[str, ...], object] = {}

    def _key(self, labels: Tuple) -> Tuple[str, ...]:
        if len(labels) != len(self.label_names):
            raise ValueError(
                f"{self.name}: got {len(labels)} label values for "
                f"label names {self.label_names}")
        return tuple(str(v) for v in labels)

    def clear(self) -> None:
        with _lock:
            self._data.clear()

    # -- snapshot helpers ----------------------------------------------------

    def _label_str(self, key: Tuple[str, ...]) -> str:
        return ",".join(f"{n}={v}" for n, v in zip(self.label_names, key))

    def _snapshot_values(self) -> Dict[str, object]:
        raise NotImplementedError


class Counter(_Metric):
    """Monotonically-increasing per-label-set float."""

    kind = "counter"

    def inc(self, *labels, by: float = 1.0) -> None:
        if not _enabled:
            return
        if by < 0:
            raise ValueError(f"{self.name}: counters only go up (by={by})")
        key = self._key(labels)
        with _lock:
            self._data[key] = self._data.get(key, 0.0) + by

    def value(self, *labels) -> float:
        with _lock:
            return float(self._data.get(self._key(labels), 0.0))

    def total(self) -> float:
        with _lock:
            return float(sum(self._data.values()))

    def _snapshot_values(self):
        return {self._label_str(k): v
                for k, v in sorted(self._data.items())}


class Gauge(_Metric):
    """Last-written per-label-set float (set/add semantics)."""

    kind = "gauge"

    def set(self, value: float, *labels) -> None:
        if not _enabled:
            return
        key = self._key(labels)
        with _lock:
            self._data[key] = float(value)

    def add(self, delta: float, *labels) -> None:
        if not _enabled:
            return
        key = self._key(labels)
        with _lock:
            self._data[key] = self._data.get(key, 0.0) + float(delta)

    def value(self, *labels) -> float:
        with _lock:
            return float(self._data.get(self._key(labels), 0.0))

    def _snapshot_values(self):
        return {self._label_str(k): v
                for k, v in sorted(self._data.items())}


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus semantics).

    Per label set: bucket counts for each upper bound in ``buckets`` plus a
    ``+Inf`` overflow bucket, a running sum and a sample count.
    """

    kind = "histogram"

    def __init__(self, name, help, label_names,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help, label_names)
        if tuple(sorted(buckets)) != tuple(buckets) or not buckets:
            raise ValueError(f"{name}: buckets must be sorted and non-empty")
        self.buckets = tuple(float(b) for b in buckets)

    def observe(self, value: float, *labels) -> None:
        if not _enabled:
            return
        key = self._key(labels)
        v = float(value)
        with _lock:
            state = self._data.get(key)
            if state is None:
                state = [[0] * (len(self.buckets) + 1), 0.0, 0]
                self._data[key] = state
            counts, _, _ = state
            for i, bound in enumerate(self.buckets):
                if v <= bound:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1
            state[1] += v
            state[2] += 1

    def count(self, *labels) -> int:
        with _lock:
            state = self._data.get(self._key(labels))
            return int(state[2]) if state else 0

    def sum(self, *labels) -> float:
        with _lock:
            state = self._data.get(self._key(labels))
            return float(state[1]) if state else 0.0

    def _snapshot_values(self):
        out = {}
        for key, (counts, total, n) in sorted(self._data.items()):
            cum, cum_counts = 0, {}
            for bound, c in zip(self.buckets, counts[:-1]):
                cum += c
                cum_counts[repr(bound)] = cum
            cum_counts["+Inf"] = cum + counts[-1]
            out[self._label_str(key)] = {
                "buckets": cum_counts, "sum": total, "count": n}
        return out


_REGISTRY: Dict[str, _Metric] = {}


def _register(cls, name: str, help: str, labels: Sequence[str], **kw):
    label_names = tuple(labels)
    with _lock:
        existing = _REGISTRY.get(name)
        if existing is not None:
            if type(existing) is not cls \
                    or existing.label_names != label_names:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{existing.kind}{existing.label_names}, cannot "
                    f"re-register as {cls.kind}{label_names}")
            return existing
        metric = cls(name, help, label_names, **kw)
        _REGISTRY[name] = metric
        return metric


def counter(name: str, help: str = "",
            labels: Sequence[str] = ()) -> Counter:
    return _register(Counter, name, help, labels)


def gauge(name: str, help: str = "", labels: Sequence[str] = ()) -> Gauge:
    return _register(Gauge, name, help, labels)


def histogram(name: str, help: str = "", labels: Sequence[str] = (),
              buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
    return _register(Histogram, name, help, labels, buckets=buckets)


def get(name: str) -> Optional[_Metric]:
    with _lock:
        return _REGISTRY.get(name)


def reset() -> None:
    """Clear every instrument's recorded values (registrations persist).
    Test/benchmark seam — call between runs for a clean snapshot."""
    with _lock:
        for m in _REGISTRY.values():
            m._data.clear()


def snapshot() -> Dict[str, dict]:
    """Deterministic JSON-ready snapshot of every registered instrument.

    Sorted by metric name; label sets sorted within each metric — two
    snapshots of the same state serialize identically (pinned by tests).
    """
    with _lock:
        return {
            name: {
                "type": m.kind,
                "help": m.help,
                "labels": list(m.label_names),
                "values": m._snapshot_values(),
            }
            for name, m in sorted(_REGISTRY.items())
        }


def write_snapshot(path: str) -> None:
    with open(path, "w") as f:
        json.dump(snapshot(), f, indent=1, sort_keys=True)
        f.write("\n")


def _prom_labels(metric: _Metric, key_str: str, extra: str = "") -> str:
    parts = []
    if key_str:
        for pair in key_str.split(","):
            n, _, v = pair.partition("=")
            v = v.replace("\\", r"\\").replace('"', r'\"')
            parts.append(f'{n}="{v}"')
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def prometheus_text() -> str:
    """Prometheus text exposition of the registry (scrape/snapshot format)."""
    lines: List[str] = []
    with _lock:
        for name, m in sorted(_REGISTRY.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key, (counts, total, n) in sorted(m._data.items()):
                    ks = m._label_str(key)
                    cum = 0
                    for bound, c in zip(m.buckets, counts[:-1]):
                        cum += c
                        le = 'le="%s"' % bound
                        lines.append(
                            f"{name}_bucket{_prom_labels(m, ks, le)} {cum}")
                    cum += counts[-1]
                    le = 'le="+Inf"'
                    lines.append(
                        f"{name}_bucket{_prom_labels(m, ks, le)} {cum}")
                    lines.append(f"{name}_sum{_prom_labels(m, ks)} {total}")
                    lines.append(f"{name}_count{_prom_labels(m, ks)} {n}")
            else:
                for key, v in sorted(m._data.items()):
                    ks = m._label_str(key)
                    lines.append(f"{name}{_prom_labels(m, ks)} {v}")
    return "\n".join(lines) + "\n"
