"""The one symmetric quantizer every integer path shares.

Three near-identical copies used to live in ``kernels/ops.quantize_symmetric``
(keepdims only when an axis was given), ``quant/qmatmul._quantize``
(keepdims always) and inline in ``quant/prequant.prequantize`` (plus a
storage-dtype cast).  The fused-kernel dequant epilogue multiplies scales
*inside* the GEMM kernel, so activation/weight scales must be produced by
exactly one rounding recipe or the Pallas and XLA backends drift apart.
This module is that recipe:

    qmax  = 2**(bits-1) - 1
    amax  = max(|x|) over ``axis`` (fp32)
    scale = max(amax, 1e-8) / qmax          (fp32)
    q     = clip(round(x / scale), -qmax, qmax)

``keepdims`` defaults to ``axis is not None`` (scales broadcast back against
``x``); pass it explicitly to force either shape.  ``storage_dtype`` selects
the integer carrier (int32 by default; prequantized weights use int8/int16).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_symmetric(x: Array, bits: int, axis=None,
                       keepdims: Optional[bool] = None,
                       storage_dtype=jnp.int32) -> Tuple[Array, Array]:
    """Symmetric signed ``bits``-bit quantization. Returns (q, scale_f32)."""
    if keepdims is None:
        keepdims = axis is not None
    xf = x.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=keepdims)
    scale = (jnp.maximum(amax, 1e-8) / qmax).astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(storage_dtype)
    return q, scale


def storage_dtype_for(bits: int):
    """Narrowest integer carrier for ``bits``-bit prequantized storage."""
    return jnp.int8 if bits <= 8 else jnp.int16
