"""Per-layer precision policy — the precision-scalable use-case (paper §II-E).

Neural networks tolerate low bitwidths for most layers but need wider ones for
a sensitive subset; a fixed-width accelerator must over-provision.  The
paper's precision-scalable KMM architecture executes each width in its best
mode (MM1 / KMM2 / MM2); this module is the model-level counterpart: a policy
assigns a bitwidth to every named matmul site, and the dispatch rule turns
that width into an execution mode.
"""
from __future__ import annotations

import fnmatch
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.dispatch import Plan, select_mode


@dataclass(frozen=True)
class QuantConfig:
    """Quantized-execution configuration attached to a model config."""

    enabled: bool = False
    default_bits: int = 8
    m: int = 8                      # multiplier (MXU operand) bitwidth
    backend: str = "xla"            # "xla" | "pallas"
    # "auto" follows the paper's dispatch rule; "mm2" forces the conventional
    # 4-product digit decomposition (the baseline KMM is measured against).
    force_mode: str = "auto"
    # fnmatch patterns on layer names -> bitwidth overrides, e.g.
    # {"*.lm_head": 12, "*.attn.o_proj": 12}
    overrides: Tuple[Tuple[str, int], ...] = ()

    def bits_for(self, name: str) -> int:
        for pattern, bits in self.overrides:
            if fnmatch.fnmatch(name, pattern):
                return bits
        return self.default_bits

    def plan_for(self, name: str) -> Plan:
        return select_mode(self.bits_for(name), self.m)


# Ready-made policies used by configs and experiments.
POLICY_W8 = QuantConfig(enabled=True, default_bits=8)
# The paper's headline regime: bitwidths 9-14 ride the KMM2 mode (4/3 roof).
POLICY_W12 = QuantConfig(enabled=True, default_bits=12)
POLICY_MIXED = QuantConfig(
    enabled=True, default_bits=8,
    overrides=(("*lm_head", 12), ("*o_proj", 12), ("*router", 12)),
)
POLICY_W16 = QuantConfig(enabled=True, default_bits=16)


@dataclass(frozen=True)
class PrecisionPolicy:
    """Workload-level summary: which fraction of GEMM work runs in each mode
    (used by benchmarks to model Table I/II mixed-width rows)."""

    bits_fractions: Tuple[Tuple[int, float], ...]  # (bits, fraction of mults)

    def mode_fractions(self, m: int = 8) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for bits, frac in self.bits_fractions:
            mode = select_mode(bits, m).mode.value
            out[mode] = out.get(mode, 0.0) + frac
        return out
