"""Pre-quantized weight storage (beyond-paper serving optimization, §Perf).

The paper-faithful quantized path re-quantizes weights from full precision on
every step (correct for STE training, wasteful for serving: each matmul reads
the fp32/bf16 weight AND materializes its integer copy).  ``prequantize``
rewrites the param tree once: every quantizable weight leaf becomes
``{"q": intN, "scale": per-channel f32}``:

  * w <= 8  -> int8 storage (4x fewer weight bytes than f32, 2x vs bf16)
  * w <= 16 -> int16 storage (2x vs f32)

``maybe_quantized_matmul`` recognizes the dict leaf and skips the runtime
weight quantization entirely — HBM weight traffic and quantize FLOPs drop out
of the compiled HLO, measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.quant.policy import QuantConfig
from repro.quant.quantize import quantize_symmetric, storage_dtype_for

Params = Any

# weight-leaf names that feed quantized matmuls, with their quantization axis
# convention: 2D (K, N) -> axis 0; 3D expert (E, K, N) -> axis 1.
_QUANT_LEAVES = {
    "wq", "wk", "wv", "wo", "wi", "wg", "wr", "w1", "w2",
    "in_proj", "out_proj", "x_proj", "dt_proj", "lm_head",
}


def _leaf_name(path) -> str:
    return str(getattr(path[-1], "key", getattr(path[-1], "name", path[-1])))


def _site_name(path) -> str:
    return ".".join(str(getattr(k, "key", getattr(k, "name", k)))
                    for k in path)


def storage_dtype(bits: int):
    return storage_dtype_for(bits)


def prequantize(params: Params, quant: QuantConfig) -> Params:
    """Replace quantizable weight leaves with {"q", "scale"} records.

    Uses the shared :mod:`repro.quant.quantize` recipe (identical rounding
    to the runtime activation/weight quantizers), so a prequantized serve
    run is bit-identical to the on-the-fly path for any backend.
    """

    def rule(path, leaf):
        name = _leaf_name(path)
        if name not in _QUANT_LEAVES or leaf.ndim < 2:
            return leaf
        bits = quant.bits_for(_site_name(path))
        axis = leaf.ndim - 2            # contraction axis (K)
        q, scale = quantize_symmetric(leaf, bits, axis=axis, keepdims=True,
                                      storage_dtype=storage_dtype_for(bits))
        return {"q": q, "scale": scale}

    return jax.tree_util.tree_map_with_path(rule, params)


def is_prequantized(wmat) -> bool:
    return isinstance(wmat, dict) and "q" in wmat and "scale" in wmat
