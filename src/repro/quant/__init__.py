from repro.quant.policy import PrecisionPolicy, QuantConfig
from repro.quant.qmatmul import quantized_matmul, quantized_matmul_batched
from repro.quant.quantize import quantize_symmetric

__all__ = [
    "PrecisionPolicy", "QuantConfig",
    "quantize_symmetric", "quantized_matmul", "quantized_matmul_batched",
]
