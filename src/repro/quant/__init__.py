from repro.quant.policy import PrecisionPolicy, QuantConfig
from repro.quant.qmatmul import quantized_matmul, quantized_matmul_batched

__all__ = [
    "PrecisionPolicy", "QuantConfig",
    "quantized_matmul", "quantized_matmul_batched",
]
