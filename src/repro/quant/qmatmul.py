"""Quantized matmul with KMM integer GEMM core and straight-through gradients.

Forward: dynamic per-token activation quantization x per-channel weight
quantization to ``w`` bits -> integer GEMM through the precision-scalable
dispatch (MM1 / KMM2 / MM2; Karatsuba digit planes for 9-14 bits) -> dequant.
Backward: straight-through estimator — gradients flow as if the matmul were
full precision (standard integer quantized-training practice; the paper's
architectures are inference-side so STE only affects our training drivers).

Two entry points: ``quantized_matmul`` for (..., K) @ (K, N) dense layers and
``quantized_matmul_batched`` for (E, C, K) @ (E, K, N) expert GEMMs.

Backends.  ``backend="xla"`` (default) lowers to ordinary dot_generals (the
digit recursion of :mod:`repro.core.kmm`) so pjit'd model code stays
GSPMD-partitionable, then dequantizes with a post-multiply.
``backend="pallas"`` routes through the fused single-pass kernel
(:mod:`repro.kernels.fused_gemm`): digit split, MXU passes, zero-point
correction **and** the dequant epilogue (sx row scale x sw col scale) run in
one ``pallas_call`` — the scales are threaded into the kernel instead of a
separate elementwise pass, and expert GEMMs ride the grouped grid axis as a
single launch.  Plans resolve through the table-backed
:func:`repro.core.dispatch.select_plan`; when the selected plan cannot run
fused (e.g. w > 2m-2, digit-accumulator headroom, a table override, or
``force_mode``), the call falls back to the XLA path.
"""
from __future__ import annotations

import functools
import math
from dataclasses import replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dispatch import analytic_plan, select_plan
from repro.core.kmm import kmm_n, max_exact_k, mm_n
from repro.kernels import ops
from repro.kernels.fused_gemm import fused_gemm, fused_gemm_grouped
from repro.quant.quantize import quantize_symmetric

Array = jax.Array

BACKENDS = ("xla", "pallas")


def _quantize(x: Array, w: int, axis) -> Tuple[Array, Array]:
    """Symmetric signed w-bit quantization along ``axis`` (None = per-tensor).

    Delegates to the shared :mod:`repro.quant.quantize` recipe with
    keepdims=True, so fused-epilogue scales and XLA post-multiply scales are
    produced by identical arithmetic.
    """
    return quantize_symmetric(x, w, axis=axis, keepdims=True)


def _dot_shape(qx: Array, qw: Array, dims) -> Tuple[int, int, int]:
    """Flattened (M, K, N) of a dot_general (batch dims folded into M)."""
    (lc, rc), (lb, rb) = dims
    k = 1
    for ax in lc:
        k *= qx.shape[ax]
    mm = 1
    for ax in range(qx.ndim):
        if ax not in lc:
            mm *= qx.shape[ax]
    n = 1
    for ax in range(qw.ndim):
        if ax not in rc and ax not in rb:
            n *= qw.shape[ax]
    return mm, k, n


def _int_dot(qx: Array, qw: Array, w: int, m: int, dims,
             force_mode: str = "auto") -> Array:
    """Integer GEMM on quantized values via the dispatched mode, fp32 out.

    Mode selection goes through the table-backed
    :func:`repro.core.dispatch.select_plan` (numerics-pinned: an installed
    tuning table can never change the computed values here, only — on
    backends where tiles matter — how they are computed), falling back to
    the paper's analytic rule when no table is active.
    """
    eplan = select_plan(_dot_shape(qx, qw, dims), w, m=m, backend="xla")
    if force_mode == "mm2" and w > m:
        return mm_n(qx, qw, w=w, n=max(eplan.digits, 2),
                    dimension_numbers=dims, combine_dtype=jnp.float32)
    if eplan.is_exact_int:
        # Every exact-class plan (mm1/xla_ref/ffip, int32-combine digit
        # variants) computes the same integer; on arbitrary dot_general dims
        # that integer is the fused int32 dot — identical to the analytic
        # w <= m path, so table/prior substitutions cannot move a bit.
        out = jax.lax.dot_general(qx, qw, dims,
                                  preferred_element_type=jnp.int32)
        return out.astype(jnp.float32)
    # fp32 class: pin_numerics guarantees variant/depth match the analytic
    # rule, so this runs exactly the paper's KMM2/MM2 digit recursion.
    fn = kmm_n if eplan.variant == "kmm2" else mm_n
    return fn(qx, qw, w=w, n=max(eplan.digits, 2), dimension_numbers=dims,
              combine_dtype=jnp.float32)


def _pow2_cover(n: int, lo: int = 8) -> int:
    v = lo
    while v < n:
        v *= 2
    return v


def _shrink_tiles(plan, shape):
    """Clamp the analytic default tiles to the runtime shape (pow2 cover,
    floor 8): serve-sized GEMMs (decode M = batch, prefill M = bucket)
    would otherwise pad every operand up to 128x256 tiles.  M/N clamping
    never affects values (padded rows/cols are sliced away and never enter
    retained outputs); the K clamp fixes the fp32-class padded K as a pure
    function of K, applied identically with or without a tuning table —
    select_plan's padded-K guard only ever adopts table tiles whose padding
    matches the un-clamped default, which the clamp preserves for every
    K >= the default block_k."""
    return replace(plan,
                   block_m=min(plan.block_m, _pow2_cover(shape[0])),
                   block_n=min(plan.block_n, _pow2_cover(shape[2])),
                   block_k=min(plan.block_k, _pow2_cover(shape[1])))


def _fused_pallas(qx: Array, qw: Array, sx: Array, sw: Array, w: int, m: int,
                  dims, out_dtype) -> Optional[Array]:
    """Run the GEMM + dequant epilogue on the Pallas backend.

    The selected plan is normally the fused single-pass kernel; a tuning
    table may redirect to a staged Pallas plan *within the same numerics
    fingerprint class* (select_plan pins it), in which case the staged
    kernel runs with a post-multiply dequant — bit-identical to the fused
    epilogue, so installing a table can never move a bit of this backend's
    output.  Returns None — the XLA fallback — only for reasons that are
    table-independent: unsupported dot_general dims, w outside the fused
    windows (the analytic pallas rule is not "fused"), or the runtime shape
    exceeding the kernel's correctness bounds (digit-accumulator / int32
    headroom).
    """
    from repro.tune.space import digit_accum_k_bound   # lazy: tune -> ops

    dense = qw.ndim == 2 and dims == (((qx.ndim - 1,), (0,)), ((), ()))
    batched = (qx.ndim == 3 and qw.ndim == 3
               and dims == (((2,), (1,)), ((0,), (0,))))
    if not dense and not batched:
        return None
    if dense:
        k_dim = qx.shape[-1]
        n_dim = qw.shape[1]
        m_dim = math.prod(qx.shape[:-1])
    else:
        _, m_dim, k_dim = qx.shape
        n_dim = qw.shape[2]
    shape = (m_dim, k_dim, n_dim)
    if analytic_plan(w, m, backend="pallas").variant != "fused":
        return None                     # MM2 window / deep recursion
    plan = select_plan(shape, w, m=m, backend="pallas")
    if plan.source == "analytic":
        plan = _shrink_tiles(plan, shape)
    # Correctness bounds (identical with or without a table; outside them
    # the XLA fallback applies either way, keeping numerics table-free).
    if plan.is_exact_int and max_exact_k(w) < k_dim:
        return None
    kp = -(-k_dim // plan.block_k) * plan.block_k
    if w > m and kp > digit_accum_k_bound(w):
        return None
    if plan.variant == "fused":
        plan = replace(plan, epilogue="dequant")
        if dense:
            out = fused_gemm(
                qx.reshape(m_dim, k_dim), qw,
                sx.reshape(m_dim, 1), sw.reshape(1, n_dim),
                w=w, m=m, block_m=plan.block_m, block_n=plan.block_n,
                block_k=plan.block_k, combine_int32=plan.combine_int32,
                out_dtype=out_dtype)
            return out.reshape(qx.shape[:-1] + (n_dim,))
        return fused_gemm_grouped(
            qx, qw, sx, sw, w=w, m=m, block_m=plan.block_m,
            block_n=plan.block_n, block_k=plan.block_k,
            combine_int32=plan.combine_int32, out_dtype=out_dtype)
    # Table/prior redirect inside the pinned fingerprint class: run the
    # selected plan through the production seam and dequant afterwards.
    if dense:
        acc = ops.run_plan(qx.reshape(m_dim, k_dim), qw, plan=plan)
        out = (acc.astype(jnp.float32)
               * (sx.reshape(m_dim, 1) * sw.reshape(1, n_dim)))
        return out.astype(out_dtype).reshape(qx.shape[:-1] + (n_dim,))
    accs = [ops.run_plan(qx[e], qw[e], plan=plan)
            for e in range(qx.shape[0])]
    acc = jnp.stack(accs).astype(jnp.float32)
    return (acc * (sx * sw)).astype(out_dtype)


def _quant_gemm(qx: Array, qw: Array, sx: Array, sw: Array, w: int, m: int,
                dims, force_mode: str, backend: str, out_dtype) -> Array:
    """Dequantized GEMM: fused Pallas kernel when routed, XLA otherwise."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choices {BACKENDS}")
    if backend == "pallas" and force_mode == "auto":
        out = _fused_pallas(qx, qw, sx, sw, w, m, dims, out_dtype)
        if out is not None:
            return out
    acc = _int_dot(qx, qw, w, m, dims, force_mode)
    return (acc * (sx * sw)).astype(out_dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def quantized_matmul(x: Array, wmat: Array, w_bits: int, m: int = 8,
                     force_mode: str = "auto",
                     backend: str = "xla") -> Array:
    """(..., K) @ (K, N) quantized to ``w_bits``; returns x.dtype."""
    return _qmm_fwd_impl(x, wmat, w_bits, m, force_mode, backend)


def _qmm_fwd_impl(x, wmat, w_bits, m, force_mode="auto", backend="xla"):
    qx, sx = _quantize(x, w_bits, axis=-1)            # per-token
    qw, sw = _quantize(wmat, w_bits, axis=0)          # per-out-channel
    dims = (((x.ndim - 1,), (0,)), ((), ()))
    return _quant_gemm(qx, qw, sx, sw, w_bits, m, dims, force_mode, backend,
                       x.dtype)


def _qmm_fwd(x, wmat, w_bits, m, force_mode="auto", backend="xla"):
    return _qmm_fwd_impl(x, wmat, w_bits, m, force_mode, backend), (x, wmat)


def _qmm_bwd(w_bits, m, force_mode, backend, res, g):
    x, wmat = res
    gf = g.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", gf, wmat.astype(jnp.float32))
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    g2 = gf.reshape(-1, gf.shape[-1])
    dw = x2.T @ g2
    return dx.astype(x.dtype), dw.astype(wmat.dtype)


quantized_matmul.defvjp(_qmm_fwd, _qmm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def quantized_matmul_batched(x: Array, wmat: Array, w_bits: int,
                             m: int = 8, force_mode: str = "auto",
                             backend: str = "xla") -> Array:
    """(E, C, K) @ (E, K, N) expert GEMM, quantized to ``w_bits``.

    On ``backend="pallas"`` all experts run as ONE grouped fused-kernel
    launch (expert axis = leading parallel grid dim) instead of an XLA
    ``kmm_n`` recursion over batched dot_generals.
    """
    return _qbmm_fwd_impl(x, wmat, w_bits, m, force_mode, backend)


def _qbmm_fwd_impl(x, wmat, w_bits, m, force_mode="auto", backend="xla"):
    qx, sx = _quantize(x, w_bits, axis=-1)            # per (expert, row)
    qw, sw = _quantize(wmat, w_bits, axis=1)          # per (expert, channel)
    dims = (((2,), (1,)), ((0,), (0,)))
    return _quant_gemm(qx, qw, sx, sw, w_bits, m, dims, force_mode, backend,
                       x.dtype)


def _qbmm_fwd(x, wmat, w_bits, m, force_mode="auto", backend="xla"):
    return _qbmm_fwd_impl(x, wmat, w_bits, m, force_mode, backend), (x, wmat)


def _qbmm_bwd(w_bits, m, force_mode, backend, res, g):
    x, wmat = res
    gf = g.astype(jnp.float32)
    dx = jnp.einsum("ecn,ekn->eck", gf, wmat.astype(jnp.float32))
    dw = jnp.einsum("eck,ecn->ekn", x.astype(jnp.float32), gf)
    return dx.astype(x.dtype), dw.astype(wmat.dtype)


quantized_matmul_batched.defvjp(_qbmm_fwd, _qbmm_bwd)


def prequant_matmul(x: Array, wrec, w_bits: int, m: int = 8,
                    force_mode: str = "auto", batched: bool = False,
                    backend: str = "xla") -> Array:
    """Serving path on pre-quantized weights ({"q", "scale"} records): skips
    the runtime weight quantization (see quant/prequant.py).  Inference-only
    (not differentiable).  ``backend="pallas"`` threads the stored
    per-channel scale straight into the fused kernel's dequant epilogue."""
    qx, sx = _quantize(x, w_bits, axis=-1)
    qw = wrec["q"].astype(jnp.int32)
    dims = (((2,), (1,)), ((0,), (0,))) if batched \
        else (((x.ndim - 1,), (0,)), ((), ()))
    return _quant_gemm(qx, qw, sx, wrec["scale"], w_bits, m, dims,
                       force_mode, backend, x.dtype)


def maybe_quantized_matmul(x: Array, wmat: Array, quant, name: str) -> Array:
    """Dense matmul that routes through the quantized KMM path when enabled."""
    if isinstance(wmat, dict):
        return prequant_matmul(x, wmat, quant.bits_for(name), quant.m,
                               quant.force_mode, backend=quant.backend)
    if quant is not None and quant.enabled:
        return quantized_matmul(x, wmat, quant.bits_for(name), quant.m,
                                quant.force_mode, quant.backend)
    return jnp.einsum("...k,kn->...n", x, wmat.astype(x.dtype))


def maybe_quantized_batched(x: Array, wmat: Array, quant, name: str) -> Array:
    if isinstance(wmat, dict):
        return prequant_matmul(x, wmat, quant.bits_for(name), quant.m,
                               quant.force_mode, batched=True,
                               backend=quant.backend)
    if quant is not None and quant.enabled:
        return quantized_matmul_batched(x, wmat, quant.bits_for(name),
                                        quant.m, quant.force_mode,
                                        quant.backend)
    return jnp.einsum("eck,ekn->ecn", x, wmat.astype(x.dtype))
