"""Quantized matmul with KMM integer GEMM core and straight-through gradients.

Forward: dynamic per-token activation quantization x per-channel weight
quantization to ``w`` bits -> integer GEMM through the precision-scalable
dispatch (MM1 / KMM2 / MM2; Karatsuba digit planes for 9-14 bits) -> dequant.
Backward: straight-through estimator — gradients flow as if the matmul were
full precision (standard integer quantized-training practice; the paper's
architectures are inference-side so STE only affects our training drivers).

Two entry points: ``quantized_matmul`` for (..., K) @ (K, N) dense layers and
``quantized_matmul_batched`` for (E, C, K) @ (E, K, N) expert GEMMs.

Execution is configured by an :class:`repro.core.context.ExecContext`
(``context=`` kwarg): backend, mesh, tuning table and force_mode in one
frozen bundle.  The legacy positional ``force_mode``/``backend`` kwargs keep
working through a shim that emits ``DeprecationWarning`` (DESIGN.md §12
migration table).

Backends.  ``backend="xla"`` (default) lowers to ordinary dot_generals (the
digit recursion of :mod:`repro.core.kmm`) so pjit'd model code stays
GSPMD-partitionable, then dequantizes with a post-multiply.
``backend="pallas"`` routes through the fused single-pass kernel
(:mod:`repro.kernels.fused_gemm`): digit split, MXU passes, zero-point
correction **and** the dequant epilogue (sx row scale x sw col scale) run in
one ``pallas_call`` — the scales are threaded into the kernel instead of a
separate elementwise pass, and expert GEMMs ride the grouped grid axis as a
single launch.  With ``context.mesh`` set, the kernel runs *shard-mapped*
over the mesh (:mod:`repro.dist.shard_gemm`): M over the data axes, N over
``model``, K replicated — bit-identical to the unsharded kernel — with
capability negotiation falling back to XLA (logged, per GEMM) when no mesh
axis tiles the problem or the local-K bounds fail.  Plans resolve through
the table-backed :func:`repro.core.dispatch.select_plan`; when the selected
plan cannot run fused (e.g. w > 2m-2, digit-accumulator headroom, a table
override, or ``force_mode``), the call falls back to the XLA path.
"""
from __future__ import annotations

import functools
import math
from dataclasses import replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.context import ExecContext, resolve_context
from repro.core.dispatch import analytic_plan, select_plan
from repro.core.kmm import kmm_n, max_exact_k, mm_n
from repro.kernels import ops
from repro.kernels.fused_gemm import fused_gemm, fused_gemm_grouped
from repro.obs import metrics as obs_metrics
from repro.quant.quantize import quantize_symmetric

Array = jax.Array

BACKENDS = ("xla", "pallas")

# Routing traffic of the quantized GEMM dispatch (trace-time, host-side:
# one hit per jit trace, a flag test when metrics are disabled).
_GEMM_ROUTES = obs_metrics.counter(
    "repro_quant_gemm_routes_total",
    "quantized-GEMM dispatch outcomes by backend and route",
    labels=("backend", "route"))
# Reasons the pallas route declined a GEMM (the table-independent XLA
# fallbacks; mesh-negotiation fallbacks count in repro.dist's counter).
_PALLAS_FALLBACKS = obs_metrics.counter(
    "repro_pallas_fallback_total",
    "pallas-route declines by reason (GEMM fell back to XLA)",
    labels=("reason",))


def _quantize(x: Array, w: int, axis) -> Tuple[Array, Array]:
    """Symmetric signed w-bit quantization along ``axis`` (None = per-tensor).

    Delegates to the shared :mod:`repro.quant.quantize` recipe with
    keepdims=True, so fused-epilogue scales and XLA post-multiply scales are
    produced by identical arithmetic.
    """
    return quantize_symmetric(x, w, axis=axis, keepdims=True)


def _dot_shape(qx: Array, qw: Array, dims) -> Tuple[int, int, int]:
    """Flattened (M, K, N) of a dot_general (batch dims folded into M)."""
    (lc, rc), (lb, rb) = dims
    k = 1
    for ax in lc:
        k *= qx.shape[ax]
    mm = 1
    for ax in range(qx.ndim):
        if ax not in lc:
            mm *= qx.shape[ax]
    n = 1
    for ax in range(qw.ndim):
        if ax not in rc and ax not in rb:
            n *= qw.shape[ax]
    return mm, k, n


def _int_dot(qx: Array, qw: Array, w: int, m: int, dims,
             force_mode: str = "auto") -> Array:
    """Integer GEMM on quantized values via the dispatched mode, fp32 out.

    Mode selection goes through the table-backed
    :func:`repro.core.dispatch.select_plan` (numerics-pinned: an installed
    tuning table can never change the computed values here, only — on
    backends where tiles matter — how they are computed), falling back to
    the paper's analytic rule when no table is active.
    """
    eplan = select_plan(_dot_shape(qx, qw, dims), w, m=m, backend="xla")
    if force_mode == "mm2" and w > m:
        return mm_n(qx, qw, w=w, n=max(eplan.digits, 2),
                    dimension_numbers=dims, combine_dtype=jnp.float32)
    if eplan.is_exact_int:
        # Every exact-class plan (mm1/xla_ref/ffip, int32-combine digit
        # variants) computes the same integer; on arbitrary dot_general dims
        # that integer is the fused int32 dot — identical to the analytic
        # w <= m path, so table/prior substitutions cannot move a bit.
        out = jax.lax.dot_general(qx, qw, dims,
                                  preferred_element_type=jnp.int32)
        return out.astype(jnp.float32)
    # fp32 class: pin_numerics guarantees variant/depth match the analytic
    # rule, so this runs exactly the paper's KMM2/MM2 digit recursion.
    fn = kmm_n if eplan.variant == "kmm2" else mm_n
    return fn(qx, qw, w=w, n=max(eplan.digits, 2), dimension_numbers=dims,
              combine_dtype=jnp.float32)


def _pow2_cover(n: int, lo: int = 8) -> int:
    v = lo
    while v < n:
        v *= 2
    return v


def _shrink_tiles(plan, shape):
    """Clamp the analytic default tiles to the runtime shape (pow2 cover,
    floor 8): serve-sized GEMMs (decode M = batch, prefill M = bucket)
    would otherwise pad every operand up to 128x256 tiles.  M/N clamping
    never affects values (padded rows/cols are sliced away and never enter
    retained outputs); the K clamp fixes the fp32-class padded K as a pure
    function of K, applied identically with or without a tuning table —
    select_plan's padded-K guard only ever adopts table tiles whose padding
    matches the un-clamped default, which the clamp preserves for every
    K >= the default block_k.

    Under a mesh this is called with the per-shard LOCAL shape: K is
    replicated by the negotiated layout, so the K clamp (hence the fp32
    padded K) is identical to the unsharded call — the M/N clamps adapt to
    the local block, which never moves a bit.
    """
    return replace(plan,
                   block_m=min(plan.block_m, _pow2_cover(shape[0])),
                   block_n=min(plan.block_n, _pow2_cover(shape[2])),
                   block_k=min(plan.block_k, _pow2_cover(shape[1])))


def _fused_plan_for(shape, w: int, m: int, context: Optional[ExecContext]):
    """Resolve + tile-clamp the pallas plan for a (local) GEMM shape, and
    check the kernel's correctness bounds.  Returns None on any bound
    failure (the XLA fallback applies, table-independent)."""
    from repro.tune.space import plan_accum_k_bound    # lazy: tune -> ops

    m_dim, k_dim, n_dim = shape
    table = context.resolve_table() if context is not None else None
    plan = select_plan(shape, w, m=m, backend="pallas", table=table)
    if plan.source == "analytic":
        plan = _shrink_tiles(plan, shape)
    # Correctness bounds (identical with or without a table; outside them
    # the XLA fallback applies either way, keeping numerics table-free).
    # The accumulator bound is plan-aware: MM2's pre-adder-free digits and
    # depth-2's quarter-width leaves stretch the exact-K window well past
    # the single-level KMM2 bound, and for the strassen variants it is the
    # composed full-problem bound (tune.space.plan_accum_k_bound).
    if plan.is_exact_int and max_exact_k(w) < k_dim:
        return None
    kp = -(-k_dim // plan.block_k) * plan.block_k
    bound = plan_accum_k_bound(plan)
    if bound is not None and kp > bound:
        return None
    return plan


def _fused_mode(plan) -> str:
    """The fused kernel's mode string for an ExecPlan routed to it."""
    if plan.variant == "fused_mm2":
        return "mm2"
    return "kmm4" if plan.depth == 2 else "auto"


def _ragged_row_mask(counts: Array, seg: int, c_dim: int) -> Array:
    """(E, C, 1) liveness of capacity-bucketed expert rows: row ``r`` is
    live iff ``r % seg < counts[e, r // seg]`` — the same predicate the
    ragged grouped kernel evaluates in-kernel, evaluated in jnp for the
    XLA fallback and staged-redirect paths so the grouped ragged contract
    (dead rows are exact zeros) holds on every backend."""
    rows = jnp.arange(c_dim, dtype=jnp.int32)
    seg_ids = rows // seg
    n_seg = counts.shape[-1]
    limit = jnp.take(counts.astype(jnp.int32),
                     jnp.clip(seg_ids, 0, n_seg - 1), axis=-1)    # (E, C)
    live = (rows - seg_ids * seg < limit) & (seg_ids < n_seg)
    return live[..., None]


def _sharded_pallas(qx: Array, qw: Array, sx: Array, sw: Array, w: int,
                    m: int, dense: bool, shape, out_dtype,
                    context: ExecContext, counts: Optional[Array] = None,
                    seg: Optional[int] = None) -> Optional[Array]:
    """Shard-mapped pallas GEMM under ``context.mesh`` (DESIGN.md §12).

    Each shard runs the unmodified kernel on its local block; the
    zero-point correction and digit accumulators stay per-shard (inside the
    kernel), and with K replicated no collective touches the accumulators —
    sharded output is bit-identical to the unsharded fused output.  Returns
    None — the logged XLA fallback — when no mesh axis tiles the GEMM or
    the plan fails its bounds on the local shape.
    """
    from repro.dist import shard_gemm as sg

    mesh = context.mesh
    n_experts = None if dense else qx.shape[0]
    spec, reason = sg.negotiate(shape, mesh, n_experts=n_experts)
    if spec is None:
        sg.log_fallback(shape, w, reason)
        return None
    lshape = sg.local_shape(shape, spec, mesh)
    plan = _fused_plan_for(lshape, w, m, context)
    if plan is None:
        sg.log_fallback(shape, w, "local-K kernel bounds failed")
        return None
    ok, reason = sg.plan_local_bounds_ok(plan, lshape, w, m)
    if not ok:
        sg.log_fallback(shape, w, reason)
        return None
    m_dim, k_dim, n_dim = shape
    if plan.variant in ("fused", "fused_mm2"):
        plan = replace(plan, epilogue="dequant", shard=spec)
        mode = _fused_mode(plan)

        def local_fused(qxl, qwl, sxl, swl, *cnt):
            fn = fused_gemm if dense else fused_gemm_grouped
            kw = {} if dense else {"counts": cnt[0] if cnt else None,
                                   "seg": seg}
            return fn(qxl, qwl, sxl, swl, w=w, m=m, mode=mode,
                      block_m=plan.block_m, block_n=plan.block_n,
                      block_k=plan.block_k,
                      combine_int32=plan.combine_int32,
                      out_dtype=out_dtype, **kw)

        if dense:
            f = sg.shard_dense_gemm(local_fused, mesh, spec)
            out = f(qx.reshape(m_dim, k_dim), qw,
                    sx.reshape(m_dim, 1), sw.reshape(1, n_dim))
            return out.reshape(qx.shape[:-1] + (n_dim,))
        return sg.shard_grouped_gemm(local_fused, mesh, spec,
                                     counts=counts)(qx, qw, sx, sw)
    # Table/prior redirect inside the pinned fingerprint class: run the
    # staged plan shard-mapped through the production seam, dequant after.
    plan = replace(plan, shard=spec)
    if dense:
        acc = sg.sharded_run_plan(qx.reshape(m_dim, k_dim), qw, plan=plan,
                                  mesh=mesh)
        out = (acc.astype(jnp.float32)
               * (sx.reshape(m_dim, 1) * sw.reshape(1, n_dim)))
        return out.astype(out_dtype).reshape(qx.shape[:-1] + (n_dim,))
    local_plan = replace(plan, shard=None)

    def local_staged(qxl, qwl, sxl, swl, *cnt):
        accs = [ops.run_plan(qxl[e], qwl[e], plan=local_plan)
                for e in range(qxl.shape[0])]
        acc = jnp.stack(accs).astype(jnp.float32)
        out = (acc * (sxl * swl)).astype(out_dtype)
        if cnt:
            out = jnp.where(_ragged_row_mask(cnt[0], seg, out.shape[1]),
                            out, jnp.zeros_like(out))
        return out

    return sg.shard_grouped_gemm(local_staged, mesh, spec,
                                 counts=counts)(qx, qw, sx, sw)


def _fused_pallas(qx: Array, qw: Array, sx: Array, sw: Array, w: int, m: int,
                  dims, out_dtype, context: Optional[ExecContext] = None,
                  counts: Optional[Array] = None,
                  seg: Optional[int] = None) -> Optional[Array]:
    """Run the GEMM + dequant epilogue on the Pallas backend.

    The selected plan is normally the fused single-pass kernel; a tuning
    table may redirect to a staged Pallas plan *within the same numerics
    fingerprint class* (select_plan pins it) — including the tile-level
    strassen variants in the exact MM1-window class — in which case the
    redirected plan runs through ``ops.run_plan`` with a post-multiply
    dequant — bit-identical to the fused epilogue, so installing a table
    can never move a bit of this backend's output.  Returns None — the XLA fallback — only for reasons that are
    table-independent: unsupported dot_general dims, w outside the fused
    windows (the analytic pallas rule is not "fused"), or the runtime shape
    exceeding the kernel's correctness bounds (digit-accumulator / int32
    headroom).  With ``context.mesh`` set the kernel runs shard-mapped
    (:func:`_sharded_pallas`); capability-negotiation failures there also
    return None, with a logged reason.
    """
    dense = qw.ndim == 2 and dims == (((qx.ndim - 1,), (0,)), ((), ()))
    batched = (qx.ndim == 3 and qw.ndim == 3
               and dims == (((2,), (1,)), ((0,), (0,))))
    if not dense and not batched:
        _PALLAS_FALLBACKS.inc("unsupported_dims")
        return None
    if dense:
        k_dim = qx.shape[-1]
        n_dim = qw.shape[1]
        m_dim = math.prod(qx.shape[:-1])
    else:
        _, m_dim, k_dim = qx.shape
        n_dim = qw.shape[2]
    shape = (m_dim, k_dim, n_dim)
    if analytic_plan(w, m, backend="pallas").variant \
            not in ("fused", "fused_mm2"):
        _PALLAS_FALLBACKS.inc("outside_fused_window")
        return None                     # recursion deeper than 2 levels
    if context is not None and context.mesh is not None \
            and not getattr(context.mesh, "empty", False):
        return _sharded_pallas(qx, qw, sx, sw, w, m, dense, shape,
                               out_dtype, context, counts=counts, seg=seg)
    plan = _fused_plan_for(shape, w, m, context)
    if plan is None:
        _PALLAS_FALLBACKS.inc("kernel_bounds")
        return None
    if plan.variant in ("fused", "fused_mm2"):
        plan = replace(plan, epilogue="dequant")
        mode = _fused_mode(plan)
        if dense:
            out = fused_gemm(
                qx.reshape(m_dim, k_dim), qw,
                sx.reshape(m_dim, 1), sw.reshape(1, n_dim),
                w=w, m=m, mode=mode, block_m=plan.block_m,
                block_n=plan.block_n, block_k=plan.block_k,
                combine_int32=plan.combine_int32, out_dtype=out_dtype)
            return out.reshape(qx.shape[:-1] + (n_dim,))
        return fused_gemm_grouped(
            qx, qw, sx, sw, counts, w=w, m=m, mode=mode, seg=seg,
            block_m=plan.block_m, block_n=plan.block_n,
            block_k=plan.block_k, combine_int32=plan.combine_int32,
            out_dtype=out_dtype)
    # Table/prior redirect inside the pinned fingerprint class: run the
    # selected plan through the production seam and dequant afterwards.
    if dense:
        acc = ops.run_plan(qx.reshape(m_dim, k_dim), qw, plan=plan)
        out = (acc.astype(jnp.float32)
               * (sx.reshape(m_dim, 1) * sw.reshape(1, n_dim)))
        return out.astype(out_dtype).reshape(qx.shape[:-1] + (n_dim,))
    accs = [ops.run_plan(qx[e], qw[e], plan=plan)
            for e in range(qx.shape[0])]
    acc = jnp.stack(accs).astype(jnp.float32)
    out = (acc * (sx * sw)).astype(out_dtype)
    if counts is not None:
        out = jnp.where(_ragged_row_mask(counts, seg, out.shape[1]),
                        out, jnp.zeros_like(out))
    return out


def _quant_gemm(qx: Array, qw: Array, sx: Array, sw: Array, w: int, m: int,
                dims, context: ExecContext, out_dtype,
                counts: Optional[Array] = None,
                seg: Optional[int] = None) -> Array:
    """Dequantized GEMM: fused Pallas kernel when routed, XLA otherwise.

    ``counts``/``seg`` (batched expert GEMMs only) make the launch ragged:
    on the pallas route the grouped kernel masks in-kernel and skips dead
    m-blocks; every other route applies the identical liveness mask to its
    output, so the contract — live rows unchanged, dead rows exact zeros —
    is backend-independent and the MoE combine sees the same tokens either
    way.
    """
    if context.backend not in BACKENDS:
        raise ValueError(f"unknown backend {context.backend!r}; "
                         f"choices {BACKENDS}")
    if context.backend == "pallas" and context.force_mode == "auto":
        out = _fused_pallas(qx, qw, sx, sw, w, m, dims, out_dtype,
                            context=context, counts=counts, seg=seg)
        if out is not None:
            _GEMM_ROUTES.inc(context.backend, "pallas")
            return out
        _GEMM_ROUTES.inc(context.backend, "xla_fallback")
    else:
        _GEMM_ROUTES.inc(context.backend, "xla")
    acc = _int_dot(qx, qw, w, m, dims, context.force_mode)
    out = (acc * (sx * sw)).astype(out_dtype)
    if counts is not None:
        out = jnp.where(_ragged_row_mask(counts, seg, out.shape[1]),
                        out, jnp.zeros_like(out))
    return out


# ---------------------------------------------------------------------------
# custom_vjp cores (STE backward).  The public entry points below are plain
# shims that resolve an ExecContext and call these; the context is a
# hashable nondiff arg (its tuning table is excluded from eq/hash and is
# installed around the traced call by the shim instead).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _qmm_core(x: Array, wmat: Array, w_bits: int, m: int,
              context: ExecContext) -> Array:
    return _qmm_fwd_impl(x, wmat, w_bits, m, context)


def _qmm_fwd_impl(x, wmat, w_bits, m, context):
    qx, sx = _quantize(x, w_bits, axis=-1)            # per-token
    qw, sw = _quantize(wmat, w_bits, axis=0)          # per-out-channel
    dims = (((x.ndim - 1,), (0,)), ((), ()))
    return _quant_gemm(qx, qw, sx, sw, w_bits, m, dims, context, x.dtype)


def _qmm_fwd(x, wmat, w_bits, m, context):
    return _qmm_fwd_impl(x, wmat, w_bits, m, context), (x, wmat)


def _qmm_bwd(w_bits, m, context, res, g):
    x, wmat = res
    gf = g.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", gf, wmat.astype(jnp.float32))
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    g2 = gf.reshape(-1, gf.shape[-1])
    dw = x2.T @ g2
    return dx.astype(x.dtype), dw.astype(wmat.dtype)


_qmm_core.defvjp(_qmm_fwd, _qmm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _qbmm_core(x: Array, wmat: Array, w_bits: int, m: int,
               context: ExecContext) -> Array:
    return _qbmm_fwd_impl(x, wmat, w_bits, m, context)


def _qbmm_fwd_impl(x, wmat, w_bits, m, context):
    qx, sx = _quantize(x, w_bits, axis=-1)            # per (expert, row)
    qw, sw = _quantize(wmat, w_bits, axis=1)          # per (expert, channel)
    dims = (((2,), (1,)), ((0,), (0,)))
    return _quant_gemm(qx, qw, sx, sw, w_bits, m, dims, context, x.dtype)


def _qbmm_fwd(x, wmat, w_bits, m, context):
    return _qbmm_fwd_impl(x, wmat, w_bits, m, context), (x, wmat)


def _qbmm_bwd(w_bits, m, context, res, g):
    x, wmat = res
    gf = g.astype(jnp.float32)
    dx = jnp.einsum("ecn,ekn->eck", gf, wmat.astype(jnp.float32))
    dw = jnp.einsum("eck,ecn->ekn", x.astype(jnp.float32), gf)
    return dx.astype(x.dtype), dw.astype(wmat.dtype)


_qbmm_core.defvjp(_qbmm_fwd, _qbmm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _qbmm_ragged_core(x: Array, wmat: Array, counts: Array, w_bits: int,
                      m: int, seg: int, context: ExecContext) -> Array:
    """Ragged batched core: ``counts`` is a *traced* integer operand (live
    token counts change per step at serve time without retracing), so it is
    a separate custom_vjp with a ``float0`` cotangent rather than a
    nondiff arg of :func:`_qbmm_core`."""
    return _qbmm_ragged_fwd_impl(x, wmat, counts, w_bits, m, seg, context)


def _qbmm_ragged_fwd_impl(x, wmat, counts, w_bits, m, seg, context):
    qx, sx = _quantize(x, w_bits, axis=-1)            # per (expert, row)
    qw, sw = _quantize(wmat, w_bits, axis=1)          # per (expert, channel)
    dims = (((2,), (1,)), ((0,), (0,)))
    return _quant_gemm(qx, qw, sx, sw, w_bits, m, dims, context, x.dtype,
                       counts=counts, seg=seg)


def _qbmm_ragged_fwd(x, wmat, counts, w_bits, m, seg, context):
    out = _qbmm_ragged_fwd_impl(x, wmat, counts, w_bits, m, seg, context)
    return out, (x, wmat, counts)


def _qbmm_ragged_bwd(w_bits, m, seg, context, res, g):
    # STE through live rows only: dead rows of the forward output are hard
    # zeros, so their cotangents must not leak into dx/dw.
    x, wmat, counts = res
    import numpy as _np
    live = _ragged_row_mask(counts, seg, x.shape[1])
    gf = jnp.where(live, g.astype(jnp.float32), 0.0)
    dx = jnp.einsum("ecn,ekn->eck", gf, wmat.astype(jnp.float32))
    dw = jnp.einsum("eck,ecn->ekn", x.astype(jnp.float32), gf)
    dc = _np.zeros(counts.shape, dtype=jax.dtypes.float0)
    return dx.astype(x.dtype), dw.astype(wmat.dtype), dc


_qbmm_ragged_core.defvjp(_qbmm_ragged_fwd, _qbmm_ragged_bwd)


# ---------------------------------------------------------------------------
# Public entry points (context-first API + deprecation shims).
# ---------------------------------------------------------------------------


def _ctx(context, force_mode, backend, what) -> ExecContext:
    return resolve_context(context, what=what, force_mode=force_mode,
                           backend=backend)


def quantized_matmul(x: Array, wmat: Array, w_bits: int, m: int = 8,
                     force_mode: Optional[str] = None,
                     backend: Optional[str] = None, *,
                     context: Optional[ExecContext] = None) -> Array:
    """(..., K) @ (K, N) quantized to ``w_bits``; returns x.dtype.

    Pass ``context=`` (an :class:`~repro.core.context.ExecContext`) to pick
    backend / mesh / tuning table / force_mode; the positional
    ``force_mode``/``backend`` kwargs are deprecated shims.
    """
    ctx = _ctx(context, force_mode, backend, "quantized_matmul")
    with ctx.activate():
        return _qmm_core(x, wmat, w_bits, m, ctx)


def quantized_matmul_batched(x: Array, wmat: Array, w_bits: int,
                             m: int = 8, force_mode: Optional[str] = None,
                             backend: Optional[str] = None, *,
                             context: Optional[ExecContext] = None,
                             counts: Optional[Array] = None,
                             seg: Optional[int] = None) -> Array:
    """(E, C, K) @ (E, K, N) expert GEMM, quantized to ``w_bits``.

    On the pallas backend all experts run as ONE grouped fused-kernel
    launch (expert axis = leading parallel grid dim) instead of an XLA
    ``kmm_n`` recursion over batched dot_generals; under ``context.mesh``
    the expert axis shards over ``model`` (expert parallelism).

    ``counts`` (E, S) int32 with static ``seg`` makes the launch *ragged*:
    expert ``e``'s C rows are S segments of ``seg`` rows, of which only the
    first ``counts[e, s]`` are live (models/moe.py passes S = batch,
    seg = capacity).  Live rows are bit-identical to the dense call; dead
    rows come out as exact zeros on every backend, and on pallas their
    m-blocks skip the MXU entirely.  ``counts`` is a traced operand (STE
    gradients flow through x/wmat only), so serve-time count changes never
    retrace.
    """
    ctx = _ctx(context, force_mode, backend, "quantized_matmul_batched")
    with ctx.activate():
        if counts is None:
            return _qbmm_core(x, wmat, w_bits, m, ctx)
        if seg is None or seg <= 0:
            raise ValueError("ragged counts need a positive static seg")
        return _qbmm_ragged_core(x, wmat, counts, w_bits, m, seg, ctx)


def prequant_matmul(x: Array, wrec, w_bits: int, m: int = 8,
                    force_mode: Optional[str] = None, batched: bool = False,
                    backend: Optional[str] = None, *,
                    context: Optional[ExecContext] = None,
                    counts: Optional[Array] = None,
                    seg: Optional[int] = None) -> Array:
    """Serving path on pre-quantized weights ({"q", "scale"} records): skips
    the runtime weight quantization (see quant/prequant.py).  Inference-only
    (not differentiable).  On the pallas backend the stored per-channel
    scale threads straight into the fused kernel's dequant epilogue.
    ``counts``/``seg`` (batched only) run the ragged grouped contract of
    :func:`quantized_matmul_batched`."""
    ctx = _ctx(context, force_mode, backend, "prequant_matmul")
    qx, sx = _quantize(x, w_bits, axis=-1)
    qw = wrec["q"].astype(jnp.int32)
    dims = (((2,), (1,)), ((0,), (0,))) if batched \
        else (((x.ndim - 1,), (0,)), ((), ()))
    if counts is not None and not batched:
        raise ValueError("ragged counts require batched=True")
    with ctx.activate():
        return _quant_gemm(qx, qw, sx, wrec["scale"], w_bits, m, dims,
                           ctx, x.dtype, counts=counts, seg=seg)


def _model_context(quant) -> ExecContext:
    """ExecContext for a model-internal GEMM, from the model's QuantConfig.

    The mesh is resolved from the ambient context (the ``with mesh:`` the
    serve engine / train loop trace under) — model code has no mesh kwarg to
    thread.  Only the pallas backend consumes it (shard-mapped kernels);
    XLA GEMMs partition via GSPMD as before.
    """
    backend = getattr(quant, "backend", "xla")
    mesh = None
    if backend == "pallas":
        from repro.dist.sharding import _ambient_mesh
        mesh = _ambient_mesh()
    return ExecContext(backend=backend, mesh=mesh,
                       force_mode=getattr(quant, "force_mode", "auto"))


def maybe_quantized_matmul(x: Array, wmat: Array, quant, name: str) -> Array:
    """Dense matmul that routes through the quantized KMM path when enabled."""
    if isinstance(wmat, dict):
        return prequant_matmul(x, wmat, quant.bits_for(name), quant.m,
                               context=_model_context(quant))
    if quant is not None and quant.enabled:
        return quantized_matmul(x, wmat, quant.bits_for(name), quant.m,
                                context=_model_context(quant))
    return jnp.einsum("...k,kn->...n", x, wmat.astype(x.dtype))


def maybe_quantized_batched(x: Array, wmat: Array, quant, name: str,
                            counts: Optional[Array] = None,
                            seg: Optional[int] = None) -> Array:
    """Expert-batched matmul through the quantized KMM path when enabled.

    ``counts``/``seg`` opt into the ragged grouped contract (dead
    capacity-bucket rows are exact zeros, live rows identical to dense) —
    the unquantized einsum path ignores them because its callers (the MoE
    combine) gather live slots only."""
    if isinstance(wmat, dict):
        return prequant_matmul(x, wmat, quant.bits_for(name), quant.m,
                               batched=True, context=_model_context(quant),
                               counts=counts, seg=seg)
    if quant is not None and quant.enabled:
        return quantized_matmul_batched(x, wmat, quant.bits_for(name),
                                        quant.m,
                                        context=_model_context(quant),
                                        counts=counts, seg=seg)
    return jnp.einsum("eck,ekn->ecn", x, wmat.astype(x.dtype))
