"""Quantized matmul with KMM integer GEMM core and straight-through gradients.

Forward: dynamic per-token activation quantization x per-channel weight
quantization to ``w`` bits -> integer GEMM through the precision-scalable
dispatch (MM1 / KMM2 / MM2; Karatsuba digit planes for 9-14 bits) -> dequant.
Backward: straight-through estimator — gradients flow as if the matmul were
full precision (standard integer quantized-training practice; the paper's
architectures are inference-side so STE only affects our training drivers).

Two entry points: ``quantized_matmul`` for (..., K) @ (K, N) dense layers and
``quantized_matmul_batched`` for (E, C, K) @ (E, K, N) expert GEMMs.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dispatch import select_plan
from repro.core.kmm import kmm_n, mm_n

Array = jax.Array


def _quantize(x: Array, w: int, axis) -> Tuple[Array, Array]:
    """Symmetric signed w-bit quantization along ``axis`` (None = per-tensor)."""
    qmax = float(2 ** (w - 1) - 1)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = (jnp.maximum(amax, 1e-8) / qmax).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int32), scale


def _dot_shape(qx: Array, qw: Array, dims) -> Tuple[int, int, int]:
    """Flattened (M, K, N) of a dot_general (batch dims folded into M)."""
    (lc, rc), (lb, rb) = dims
    k = 1
    for ax in lc:
        k *= qx.shape[ax]
    mm = 1
    for ax in range(qx.ndim):
        if ax not in lc:
            mm *= qx.shape[ax]
    n = 1
    for ax in range(qw.ndim):
        if ax not in rc and ax not in rb:
            n *= qw.shape[ax]
    return mm, k, n


def _int_dot(qx: Array, qw: Array, w: int, m: int, dims,
             force_mode: str = "auto") -> Array:
    """Integer GEMM on quantized values via the dispatched mode, fp32 out.

    Mode selection goes through the table-backed
    :func:`repro.core.dispatch.select_plan` (numerics-pinned: an installed
    tuning table can never change the computed values here, only — on
    backends where tiles matter — how they are computed), falling back to
    the paper's analytic rule when no table is active.
    """
    eplan = select_plan(_dot_shape(qx, qw, dims), w, m=m, backend="xla")
    if force_mode == "mm2" and w > m:
        return mm_n(qx, qw, w=w, n=max(eplan.digits, 2),
                    dimension_numbers=dims, combine_dtype=jnp.float32)
    if eplan.is_exact_int:
        # Every exact-class plan (mm1/xla_ref/ffip, int32-combine digit
        # variants) computes the same integer; on arbitrary dot_general dims
        # that integer is the fused int32 dot — identical to the analytic
        # w <= m path, so table/prior substitutions cannot move a bit.
        out = jax.lax.dot_general(qx, qw, dims,
                                  preferred_element_type=jnp.int32)
        return out.astype(jnp.float32)
    # fp32 class: pin_numerics guarantees variant/depth match the analytic
    # rule, so this runs exactly the paper's KMM2/MM2 digit recursion.
    fn = kmm_n if eplan.variant == "kmm2" else mm_n
    return fn(qx, qw, w=w, n=max(eplan.digits, 2), dimension_numbers=dims,
              combine_dtype=jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def quantized_matmul(x: Array, wmat: Array, w_bits: int, m: int = 8,
                     force_mode: str = "auto") -> Array:
    """(..., K) @ (K, N) quantized to ``w_bits``; returns x.dtype."""
    return _qmm_fwd_impl(x, wmat, w_bits, m, force_mode)


def _qmm_fwd_impl(x, wmat, w_bits, m, force_mode="auto"):
    qx, sx = _quantize(x, w_bits, axis=-1)            # per-token
    qw, sw = _quantize(wmat, w_bits, axis=0)          # per-out-channel
    dims = (((x.ndim - 1,), (0,)), ((), ()))
    acc = _int_dot(qx, qw, w_bits, m, dims, force_mode)
    return (acc * (sx * sw)).astype(x.dtype)


def _qmm_fwd(x, wmat, w_bits, m, force_mode="auto"):
    return _qmm_fwd_impl(x, wmat, w_bits, m, force_mode), (x, wmat)


def _qmm_bwd(w_bits, m, force_mode, res, g):
    x, wmat = res
    gf = g.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", gf, wmat.astype(jnp.float32))
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    g2 = gf.reshape(-1, gf.shape[-1])
    dw = x2.T @ g2
    return dx.astype(x.dtype), dw.astype(wmat.dtype)


quantized_matmul.defvjp(_qmm_fwd, _qmm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def quantized_matmul_batched(x: Array, wmat: Array, w_bits: int,
                             m: int = 8, force_mode: str = "auto") -> Array:
    """(E, C, K) @ (E, K, N) expert GEMM, quantized to ``w_bits``."""
    return _qbmm_fwd_impl(x, wmat, w_bits, m, force_mode)


def _qbmm_fwd_impl(x, wmat, w_bits, m, force_mode="auto"):
    qx, sx = _quantize(x, w_bits, axis=-1)            # per (expert, row)
    qw, sw = _quantize(wmat, w_bits, axis=1)          # per (expert, channel)
    dims = (((2,), (1,)), ((0,), (0,)))
    acc = _int_dot(qx, qw, w_bits, m, dims, force_mode)
    return (acc * (sx * sw)).astype(x.dtype)


def _qbmm_fwd(x, wmat, w_bits, m, force_mode="auto"):
    return _qbmm_fwd_impl(x, wmat, w_bits, m, force_mode), (x, wmat)


def _qbmm_bwd(w_bits, m, force_mode, res, g):
    x, wmat = res
    gf = g.astype(jnp.float32)
    dx = jnp.einsum("ecn,ekn->eck", gf, wmat.astype(jnp.float32))
    dw = jnp.einsum("eck,ecn->ekn", x.astype(jnp.float32), gf)
    return dx.astype(x.dtype), dw.astype(wmat.dtype)


quantized_matmul_batched.defvjp(_qbmm_fwd, _qbmm_bwd)


def prequant_matmul(x: Array, wrec, w_bits: int, m: int = 8,
                    force_mode: str = "auto", batched: bool = False) -> Array:
    """Serving path on pre-quantized weights ({"q", "scale"} records): skips
    the runtime weight quantization (see quant/prequant.py).  Inference-only
    (not differentiable)."""
    qx, sx = _quantize(x, w_bits, axis=-1)
    qw = wrec["q"].astype(jnp.int32)
    dims = (((2,), (1,)), ((0,), (0,))) if batched         else (((x.ndim - 1,), (0,)), ((), ()))
    acc = _int_dot(qx, qw, w_bits, m, dims, force_mode)
    return (acc * (sx * wrec["scale"])).astype(x.dtype)


def maybe_quantized_matmul(x: Array, wmat: Array, quant, name: str) -> Array:
    """Dense matmul that routes through the quantized KMM path when enabled."""
    if isinstance(wmat, dict):
        return prequant_matmul(x, wmat, quant.bits_for(name), quant.m,
                               quant.force_mode)
    if quant is not None and quant.enabled:
        return quantized_matmul(x, wmat, quant.bits_for(name), quant.m,
                                quant.force_mode)
    return jnp.einsum("...k,kn->...n", x, wmat.astype(x.dtype))


def maybe_quantized_batched(x: Array, wmat: Array, quant, name: str) -> Array:
    if isinstance(wmat, dict):
        return prequant_matmul(x, wmat, quant.bits_for(name), quant.m,
                               quant.force_mode, batched=True)
    if quant is not None and quant.enabled:
        return quantized_matmul_batched(x, wmat, quant.bits_for(name),
                                        quant.m, quant.force_mode)
    return jnp.einsum("eck,ekn->ecn", x, wmat.astype(x.dtype))
