"""seamless-m4t-medium [audio]: encoder-decoder, 12L enc + 12L dec,
d_model=1024 16H (kv=16) d_ff=4096 vocab=256206; speech frontend is a STUB —
the input spec provides precomputed fbank frame features (dim 160) projected
into the encoder stream [arXiv:2308.11596]."""
from repro.models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    pattern=(Block("attn"),),
    n_periods=12,            # decoder depth
    encoder_periods=12,      # encoder depth
    act="gelu",
    glu=False,
    rope_theta=10000.0,
    tie_embeddings=True,
    frontend="audio",
    frontend_dim=160,
    n_microbatches=2,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=512, n_periods=2, encoder_periods=2, frontend_dim=32,
)
