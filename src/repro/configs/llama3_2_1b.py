"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256, SwiGLU [hf:meta-llama/Llama-3.2-1B]."""
from repro.models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    pattern=(Block("attn"),),
    n_periods=16,
    act="silu",
    glu=True,
    rope_theta=500000.0,
    tie_embeddings=True,
    n_microbatches=2,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, n_periods=2,
)
