"""Architecture registry: ``get_config(arch, smoke=False, quant=...)``.

Input-shape cells (LM-family, per assignment):
  train_4k     seq_len=4096   global_batch=256  (training, train_step)
  prefill_32k  seq_len=32768  global_batch=32   (inference prefill)
  decode_32k   seq_len=32768  global_batch=128  (one-token decode w/ KV cache)
  long_500k    seq_len=524288 global_batch=1    (long-context decode;
               sub-quadratic archs only — see DESIGN.md §6)
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, Optional

from repro.models.config import ModelConfig
from repro.quant.policy import QuantConfig, POLICY_MIXED, POLICY_W12, POLICY_W8

_MODULES = {
    "gemma-2b": "gemma_2b",
    "nemotron-4-15b": "nemotron_4_15b",
    "stablelm-12b": "stablelm_12b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "rwkv6-3b": "rwkv6_3b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

QUANT_POLICIES = {
    "none": QuantConfig(),
    "w8": POLICY_W8,
    "w12": POLICY_W12,
    "mixed": POLICY_MIXED,
    # conventional 4-product digit GEMM at the same width: the paper's
    # baseline that KMM2's 3 products are measured against (§Perf)
    "w12-mm2": QuantConfig(enabled=True, default_bits=12, force_mode="mm2"),
}


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def list_archs():
    return sorted(_MODULES)


def get_config(arch: str, *, smoke: bool = False,
               quant: Optional[str] = None) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choices: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg: ModelConfig = mod.SMOKE if smoke else mod.CONFIG
    if quant is not None:
        cfg = cfg.with_quant(QUANT_POLICIES[quant])
    return cfg


def cell_applicable(cfg: ModelConfig, shape: str) -> bool:
    """The assignment's skip rules (documented in DESIGN.md §6)."""
    cell = SHAPES[shape]
    if cell.name == "long_500k":
        return cfg.sub_quadratic
    return True
