"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai/stablelm-2-12b]."""
from repro.models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    pattern=(Block("attn"),),
    n_periods=40,
    act="silu",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=False,
    n_microbatches=8,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, n_periods=2,
)
