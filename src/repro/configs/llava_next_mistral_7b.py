"""llava-next-mistral-7b [vlm]: Mistral-7B backbone, 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000; anyres vision frontend is a STUB — the
input spec provides precomputed CLIP patch embeddings (dim 1024) which a
2-layer GELU projector maps into the LM stream
[hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from repro.models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    pattern=(Block("attn"),),
    n_periods=32,
    act="silu",
    glu=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    frontend="vision",
    frontend_dim=1024,
    frontend_tokens=576,
    n_microbatches=8,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, n_periods=2, frontend_dim=32, frontend_tokens=8,
)
