"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    pattern=(Block("attn", moe=True),),
    n_periods=48,
    act="silu",
    glu=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    n_experts=128,
    top_k=8,
    d_ff_expert=768,
    n_microbatches=8,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96,
    vocab_size=512, n_periods=2, n_experts=8, top_k=2, d_ff_expert=96,
)
