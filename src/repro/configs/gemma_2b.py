"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256 [arXiv:2403.08295]."""
from repro.models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    pattern=(Block("attn"),),
    n_periods=18,
    act="gelu",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    n_microbatches=2,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
    vocab_size=512, n_periods=2,
)
