"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000, squared-ReLU (no GLU) [arXiv:2402.16819]."""
from repro.models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    pattern=(Block("attn"),),
    n_periods=32,
    act="relu2",
    glu=False,
    rope_theta=10000.0,
    tie_embeddings=False,
    n_microbatches=8,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, n_periods=2,
)
