"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba:attention 1:7 interleave
[arXiv:2403.19887].

One period = 8 layers: attention at offset 4 (attn_layer_period=8,
attn_layer_offset=4) and MoE every other layer (expert_layer_period=2,
expert_layer_offset=1), exactly the Jamba paper's layout.
"""
from repro.models.config import Block, ModelConfig

_PERIOD = tuple(
    Block("attn" if i == 4 else "mamba", moe=(i % 2 == 1)) for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    pattern=_PERIOD,
    n_periods=4,
    act="silu",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=False,
    n_experts=16,
    top_k=2,
    d_ff_expert=14336,
    d_state=16,
    conv_width=4,
    expand=2,
    n_microbatches=8,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96,
    vocab_size=512, n_periods=1, n_experts=4, top_k=2, d_ff_expert=96,
    d_state=8,
)
