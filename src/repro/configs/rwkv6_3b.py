"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892].

Channel-mix FFN modeled as a squared-ReLU MLP (RWKV's channel mix uses
relu^2); time mix is the RWKV6 matrix-state recurrence in models/rwkv.py.
"""
from repro.models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    d_model=2560,
    n_heads=40,            # d_model / rwkv_head_dim; informational
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    pattern=(Block("rwkv"),),
    n_periods=32,
    act="relu2",
    glu=False,
    tie_embeddings=False,
    rwkv_head_dim=64,
    n_microbatches=4,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=512, n_periods=2, rwkv_head_dim=16,
)
