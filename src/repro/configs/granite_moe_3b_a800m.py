"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512 vocab=49155, MoE 40 experts top-8 [hf:ibm-granite]."""
from repro.models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    pattern=(Block("attn", moe=True),),
    n_periods=32,
    act="silu",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    n_experts=40,
    top_k=8,
    d_ff_expert=512,
    n_microbatches=8,
)

SMOKE = CONFIG.scaled_down(
    n_microbatches=1,
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96,
    vocab_size=512, n_periods=2, n_experts=8, top_k=2, d_ff_expert=96,
)
