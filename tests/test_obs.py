"""Observability-layer tests (repro.obs: metrics / trace / traffic).

Pins the three contracts DESIGN.md §14 promises:

  * metrics registry semantics — counter/gauge/histogram math, idempotent
    registration, deterministic snapshots, thread-safety under concurrent
    writers, Prometheus text shape, and the disabled path recording nothing;
  * span tracer — contextvar nesting (depth/parent), Chrome trace-event
    schema of the export, async begin/end pairing, and the shared null-span
    singleton on the disabled path;
  * traffic harness — cost_analysis bytes/flops validated against a
    hand-computed plain matmul, and the measured-vs-analytic rows/checks on
    a tiny shape;

plus the acceptance bar: serve tokens are bit-identical with observability
fully enabled vs fully disabled, and steady-state decode shows zero
retraces beyond the per-bucket-width compiles.
"""
import json
import threading

import numpy as np
import pytest
import jax

from repro.obs import metrics, trace, traffic


@pytest.fixture()
def obs_on():
    """Enable metrics+trace with clean state; restore disabled-and-clean."""
    metrics.reset()
    trace.clear()
    metrics.enable()
    trace.enable()
    try:
        yield
    finally:
        metrics.disable()
        trace.disable()
        metrics.reset()
        trace.clear()


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_disabled_records_nothing():
    metrics.reset()
    assert not metrics.enabled()
    c = metrics.counter("t_disabled_total", labels=("k",))
    g = metrics.gauge("t_disabled_gauge")
    h = metrics.histogram("t_disabled_seconds")
    c.inc("a")
    g.set(5.0)
    h.observe(0.2)
    assert c.value("a") == 0.0 and c.total() == 0.0
    assert g.value() == 0.0
    assert h.count() == 0 and h.sum() == 0.0


def test_counter_semantics(obs_on):
    c = metrics.counter("t_counter_total", "help", labels=("route",))
    c.inc("fast")
    c.inc("fast", by=2)
    c.inc("slow", by=0.5)
    assert c.value("fast") == 3.0
    assert c.value("slow") == 0.5
    assert c.total() == 3.5
    with pytest.raises(ValueError):
        c.inc("fast", by=-1)
    with pytest.raises(ValueError):
        c.inc()                      # label arity mismatch


def test_gauge_set_add(obs_on):
    g = metrics.gauge("t_gauge")
    g.set(4.0)
    g.set(2.0)
    g.add(0.5)
    assert g.value() == 2.5


def test_histogram_buckets_cumulative(obs_on):
    h = metrics.histogram("t_lat_seconds", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count() == 5
    assert h.sum() == pytest.approx(5.605)
    snap = h._snapshot_values()[""]
    # Prometheus semantics: cumulative counts, +Inf == total count.
    assert snap["buckets"] == {"0.01": 1, "0.1": 3, "1.0": 4, "+Inf": 5}


def test_registration_idempotent_and_conflicting():
    c1 = metrics.counter("t_reg_total", labels=("a",))
    c2 = metrics.counter("t_reg_total", labels=("a",))
    assert c1 is c2
    with pytest.raises(ValueError):
        metrics.counter("t_reg_total", labels=("b",))     # label mismatch
    with pytest.raises(ValueError):
        metrics.gauge("t_reg_total", labels=("a",))       # kind mismatch


def test_snapshot_deterministic_and_reset(obs_on):
    c = metrics.counter("t_snap_total", labels=("x",))
    c.inc("b")
    c.inc("a")
    s1 = json.dumps(metrics.snapshot(), sort_keys=True)
    s2 = json.dumps(metrics.snapshot(), sort_keys=True)
    assert s1 == s2
    doc = metrics.snapshot()["t_snap_total"]
    assert doc["type"] == "counter"
    assert list(doc["values"]) == ["x=a", "x=b"]          # sorted label sets
    metrics.reset()
    assert metrics.snapshot()["t_snap_total"]["values"] == {}
    assert metrics.get("t_snap_total") is c               # registration kept


def test_prometheus_text(obs_on):
    c = metrics.counter("t_prom_total", "prom help", labels=("r",))
    c.inc("x", by=2)
    h = metrics.histogram("t_prom_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(5.0)
    txt = metrics.prometheus_text()
    assert "# HELP t_prom_total prom help" in txt
    assert "# TYPE t_prom_total counter" in txt
    assert 't_prom_total{r="x"} 2.0' in txt
    assert 't_prom_seconds_bucket{le="0.1"} 1' in txt
    assert 't_prom_seconds_bucket{le="+Inf"} 2' in txt
    assert "t_prom_seconds_count 2" in txt


def test_counter_thread_safety(obs_on):
    c = metrics.counter("t_threads_total", labels=("t",))
    n_threads, n_incs = 8, 500

    def worker(i):
        for _ in range(n_incs):
            c.inc(i % 2)

    ts = [threading.Thread(target=worker, args=(i,))
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.total() == n_threads * n_incs


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------


def test_disabled_span_is_shared_null():
    assert not trace.enabled()
    s1 = trace.span("a", k=1)
    s2 = trace.span("b")
    assert s1 is s2                   # singleton: no per-call allocation
    with s1 as sp:
        sp.set(x=2)                   # no-op, no error
    trace.instant("nothing")
    assert trace.events() == []


def test_span_nesting_and_chrome_schema(obs_on):
    with trace.span("outer", step=1):
        with trace.span("inner", w=4) as sp:
            sp.set(late=True)
    trace.instant("marker", y=2)
    trace.begin_async("request", 7, prompt_len=3)
    trace.end_async("request", 7, reason="length")

    doc = trace.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    ev = {e["name"]: e for e in doc["traceEvents"]}
    assert set(ev) == {"outer", "inner", "marker", "request"}

    inner, outer = ev["inner"], ev["outer"]
    for e in (inner, outer):
        assert e["ph"] == "X" and e["cat"] == "repro"
        assert isinstance(e["ts"], float) and e["dur"] >= 0
        assert "pid" in e and "tid" in e
    assert outer["args"]["depth"] == 0
    assert inner["args"]["depth"] == 1
    assert inner["args"]["parent"] == "outer"
    assert inner["args"]["late"] is True
    # inner nests inside outer on the timeline
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]

    assert ev["marker"]["ph"] == "i"
    # async pair shares (name, id); begin carries the open attrs
    reqs = [e for e in doc["traceEvents"] if e["name"] == "request"]
    assert sorted(e["ph"] for e in reqs) == ["b", "e"]
    assert all(e["id"] == "7" for e in reqs)

    json.dumps(doc)                   # schema is JSON-serializable as-is


def test_export_chrome(obs_on, tmp_path):
    with trace.span("one"):
        pass
    out = tmp_path / "trace.json"
    trace.export_chrome(str(out))
    doc = json.loads(out.read_text())
    assert [e["name"] for e in doc["traceEvents"]] == ["one"]


# ---------------------------------------------------------------------------
# Traffic harness
# ---------------------------------------------------------------------------


def test_measure_costs_known_matmul():
    """cost_analysis bytes/flops against a hand-computed f32 matmul:
    (64,64)@(64,64) reads two operands, writes one output (3*64*64*4
    bytes) and does 2*64^3 flops."""
    import jax.numpy as jnp

    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    got = traffic.measure_costs(jax.jit(lambda a, b: a @ b)
                                .lower(spec, spec))
    assert got["method"] in ("cost_analysis", "hlo_text")
    assert got["flops"] == pytest.approx(2 * 64 ** 3)
    assert got["bytes"] == pytest.approx(3 * 64 * 64 * 4, rel=0.05)
    # the analytic xla model is exactly this floor
    assert traffic.analytic_bytes("xla", (64, 64, 64)) == 3 * 64 * 64 * 4


def test_analytic_bytes_models():
    shape, tiles = (64, 256, 64), (64, 64, 64)
    fused = traffic.analytic_bytes("fused", shape, w=12, tiles=tiles)
    staged = traffic.analytic_bytes("staged", shape, w=12, tiles=tiles)
    xla = traffic.analytic_bytes("xla", shape)
    # the paper's claim, in the model itself: fused < staged, both real
    assert 0 < fused < staged
    assert xla == 4 * (64 * 256 + 256 * 64) + 4 * 64 * 64
    # w<=m drops the fused operand carrier to s8 (half the plane reads)
    assert traffic.analytic_bytes("fused", shape, w=8, tiles=tiles) < fused
    with pytest.raises(ValueError):
        traffic.analytic_bytes("nope", shape, tiles=tiles)


def test_traffic_rows_and_checks_smoke():
    shapes = traffic.SMOKE_SHAPES[:1]
    rows = traffic.traffic_rows(shapes, w=traffic.DEFAULT_W)
    measured = [r for r in rows if "measured_bytes" in r]
    assert {r["kind"] for r in measured} == set(traffic.TRAFFIC_KINDS)
    assert all(r["measured_bytes"] > 0 for r in measured)
    assert all(r["analytic_bytes"] > 0 for r in measured)
    ratio_rows = [r for r in rows if "bytes_ratio" in r]
    assert len(ratio_rows) == 1
    checks = traffic.traffic_checks(rows)
    failed = [c for c in checks if not c[1]]
    assert not failed, failed
    # the committed claim on this shape: fused moves fewer bytes
    assert ratio_rows[0]["bytes_ratio"] < 1.0


def test_measure_plan_bytes_swallows_failure():
    class Bogus:                      # not an ExecPlan: lower() must fail
        pass
    assert traffic.measure_plan_bytes(Bogus(), None, None) == 0.0


def test_tune_runner_records_bytes():
    from repro.tune import runner

    res = runner.tune_shape((32, 64, 32), 8, backend="pallas", iters=1,
                            tile_choices=(32,), max_candidates=2)
    ok = [m for m in res.measurements if m.ok]
    assert ok and all(m.bytes > 0 for m in ok)
    off = runner.tune_shape((32, 64, 32), 8, backend="pallas", iters=1,
                            tile_choices=(32,), max_candidates=1,
                            record_bytes=False)
    assert all(m.bytes == 0.0 for m in off.measurements)


# ---------------------------------------------------------------------------
# Serve: obs on/off token identity + steady-state retraces
# ---------------------------------------------------------------------------


def _tiny_cfg():
    from repro.configs import get_config
    return get_config("llama3.2-1b", smoke=True).scaled_down(
        d_model=64, d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
        head_dim=16)


@pytest.fixture(scope="module")
def tiny():
    from repro.models import lm
    cfg = _tiny_cfg()
    params = lm.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _generate(cfg, params):
    from repro.serve.engine import Engine, Request
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, size=n)),
                    max_new_tokens=m, temperature=t)
            for n, m, t in ((3, 6, 0.0), (9, 3, 0.7), (5, 5, 0.0))]
    eng = Engine(cfg, params, max_seq=32, batch_size=2, rng_seed=3)
    eng.generate(reqs)
    return [r.generated for r in reqs], eng


def test_serve_tokens_identical_with_obs_enabled(tiny):
    cfg, params = tiny
    baseline, _ = _generate(cfg, params)

    metrics.reset()
    trace.clear()
    metrics.enable()
    trace.enable()
    try:
        observed, eng = _generate(cfg, params)
        assert observed == baseline   # enabling obs moves no bits

        snap = metrics.snapshot()
        assert snap["repro_serve_admitted_total"]["values"][""] == 3.0
        fin = snap["repro_serve_finished_total"]["values"]
        assert sum(fin.values()) == 3.0
        ttft = snap["repro_serve_ttft_seconds"]["values"][""]
        assert ttft["count"] == 3

        # Steady-state decode must not retrace: every counted (re)compile
        # is one of the per-bucket-width traces the executor reports.
        retr = metrics.get("repro_serve_retraces_total")
        assert retr.value("decode") == eng.n_traces()["decode"]

        names = {e["name"] for e in trace.events()}
        assert {"engine_step", "decode_step", "request"} <= names
        reqs = [e for e in trace.events() if e["name"] == "request"]
        assert sorted(e["ph"] for e in reqs) == ["b"] * 3 + ["e"] * 3
    finally:
        metrics.disable()
        trace.disable()
        metrics.reset()
        trace.clear()

    # and back off: still identical (no sticky state)
    again, _ = _generate(cfg, params)
    assert again == baseline
