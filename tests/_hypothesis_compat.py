"""Offline fallback for ``hypothesis``.

CI and the dev container may not have hypothesis installed (no network at
test time).  When the real package is available it is re-exported verbatim;
otherwise ``given``/``settings``/``strategies`` are backed by fixed-seed
sampled cases: each ``@given`` test runs ``max_examples`` times with values
drawn from a numpy Generator seeded by the test's qualified name, so runs
are deterministic across machines and give real (if non-shrinking)
property coverage.

Usage in test modules:

    from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

import os
import zlib

try:   # real hypothesis when installed (the `test` extra)
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

    def settings(max_examples=10, deadline=None, **_):
        """Records max_examples on the (given-wrapped) test function."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(fn):
            # NOTE: no functools.wraps — pytest would follow __wrapped__ to
            # the original signature and demand fixtures for the sampled
            # parameters.  Copy identity attributes by hand instead.
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", 10)
                cap = os.environ.get("COMPAT_MAX_EXAMPLES")
                if cap:
                    n = min(n, int(cap))
                seed0 = zlib.crc32(fn.__qualname__.encode("utf-8"))
                for i in range(n):
                    rng = np.random.default_rng((seed0 + i) & 0xFFFFFFFF)
                    drawn = {name: s.draw(rng)
                             for name, s in strategy_kwargs.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"{fn.__qualname__} failed on sampled case "
                            f"{drawn!r} (example {i + 1}/{n})") from e

            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(fn, attr))
            wrapper.hypothesis_compat_fallback = True
            return wrapper

        return deco
