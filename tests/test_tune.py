"""repro.tune: search-space pruning, bit-exact candidate sweep, table
persistence, and the dispatch-seam guarantees (headroom + pinned numerics).
"""
import json

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.dispatch import (ExecPlan, Mode, analytic_plan,
                                 numerics_fingerprint, select_mode,
                                 select_plan)
from repro.core.kmm import max_exact_k
from repro.kernels import ops
from repro.kernels.ref import ref_int_gemm_i64
from repro.quant.qmatmul import quantized_matmul, quantized_matmul_batched
from repro.tune import runner, space
from repro.tune.table import TuningTable, get_active_table, key_for, use_table

SHAPE = (16, 32, 16)          # small M/K/N: every candidate runs in ms
TILES = (32, 64)


# ---------------------------------------------------------------------------
# Satellite: dispatch-rule validation.
# ---------------------------------------------------------------------------


def test_select_mode_rejects_m_below_2():
    for m in (0, 1, -3):
        with pytest.raises(ValueError, match="must be >= 2"):
            select_mode(8, m=m)


def test_w_2m_minus_1_boundary_is_mm2():
    """w = 2m - 1 lands in MM2 by design: the Karatsuba pre-adder digits
    need m + 1 bits there (documented in the select_mode docstring)."""
    for m in (4, 8):
        plan = select_mode(2 * m - 1, m=m)
        assert plan.mode is Mode.MM2 and plan.passes == 4
        assert select_mode(2 * m - 2, m=m).mode is Mode.KMM2


# ---------------------------------------------------------------------------
# Satellite: every pruned-space candidate is bit-exact vs kernels/ref.py.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", [4, 8, 12, 14])
def test_pruned_space_bit_exact_vs_ref(w):
    """Interpret-mode tile sweep: every candidate the pruner admits must
    reproduce kernels/ref.py bit-for-bit — exact-int candidates against the
    int64 oracle, fp32-combine candidates against the pure-jnp ref-kernel
    mirror (identical padding + zero-point correction)."""
    cands = space.pruned_space(SHAPE, w, backend="pallas",
                               tile_choices=TILES)
    assert cands, f"empty pruned space at w={w}"
    a, b = runner.make_operands(SHAPE, w, seed=w)
    a_np, b_np = np.asarray(a), np.asarray(b)
    oracle = ref_int_gemm_i64(a_np, b_np)
    seen_variants = set()
    for plan in cands:
        assert space.validate(plan, SHAPE) is None
        out = np.asarray(ops.run_plan_jit(a, b, plan))
        if plan.is_exact_int:
            np.testing.assert_array_equal(
                out.astype(np.int64), oracle,
                err_msg=f"exact candidate diverged: {plan}")
        else:
            mirror = np.asarray(ops.run_plan_jit(a, b, plan,
                                                 use_ref_kernels=True))
            np.testing.assert_array_equal(
                out, mirror, err_msg=f"fp32 candidate diverged: {plan}")
        seen_variants.add(plan.variant)
    assert "kmm2" in seen_variants or w <= 2   # KMM2 covers w in [2, 14]
    if w == 14:
        # headroom pruning must have dropped every int32-combine candidate:
        # max_exact_k(14) = 8 < K = 32
        assert all(not p.combine_int32 for p in cands)
        assert all(p.variant not in ("xla_ref", "ffip") for p in cands)


def test_xla_digit_space_exact_candidates_bit_exact():
    w = 12
    cands = [p for p in space.candidates(SHAPE, w, backend="xla")
             if p.combine_int32]
    assert any(p.depth > 1 for p in cands)     # plan-depth is a real knob
    a, b = runner.make_operands(SHAPE, w, seed=3)
    oracle = ref_int_gemm_i64(np.asarray(a), np.asarray(b))
    for plan in cands:
        out = np.asarray(ops.run_plan_jit(a, b, plan))
        np.testing.assert_array_equal(out.astype(np.int64), oracle,
                                      err_msg=str(plan))


# ---------------------------------------------------------------------------
# Space pruning + cost prior.
# ---------------------------------------------------------------------------


def test_validate_rejects_headroom_violations():
    bad = ExecPlan("kmm2", 14, backend="pallas", block_m=32, block_n=32,
                   block_k=32, combine_int32=True)
    reason = space.validate(bad, (64, 128, 64))
    assert reason is not None and "headroom" in reason
    # mm1's single int8 accumulation has the same bound: at w=8, K=2^18 the
    # worst case K*(2^7)^2 = 2^32 overflows int32 — and select_plan must
    # refuse such a table entry
    big_k = (128, 1 << 18, 128)
    mm1_bad = ExecPlan("mm1", 8, backend="pallas", combine_int32=True)
    assert space.validate(mm1_bad, big_k) is not None
    t = TuningTable()
    t.put("pallas", big_k, 8, mm1_bad)
    with use_table(t):
        # prior also can't offer anything at this K (all exact-class
        # candidates fail headroom), so the analytic rule survives
        assert select_plan(big_k, 8, backend="pallas").source == "analytic"
    assert space.validate(
        ExecPlan("xla_ref", 14, combine_int32=True), (64, 128, 64)
    ) is not None
    # mm1 outside its window
    assert space.validate(
        ExecPlan("mm1", 12, backend="pallas", combine_int32=True),
        SHAPE) is not None
    # kmm2 past the paper's 2m-2 window on pallas
    assert space.validate(
        ExecPlan("kmm2", 16, backend="pallas", block_m=32, block_n=32,
                 block_k=32), SHAPE) is not None


def test_cost_prior_prefers_kmm2_over_mm2():
    k2 = ExecPlan("kmm2", 12, backend="pallas", block_m=32, block_n=32,
                  block_k=32)
    m2 = ExecPlan("mm2", 12, backend="pallas", block_m=32, block_n=32,
                  block_k=32)
    assert space.cost_prior(k2, SHAPE) < space.cost_prior(m2, SHAPE)


def test_prior_plan_stays_in_analytic_numerics_class():
    for backend in ("xla", "pallas"):
        for w in (8, 12):
            prior = space.prior_plan(SHAPE, w, backend=backend)
            assert prior is not None and prior.source == "prior"
            base = analytic_plan(w, backend=backend)
            assert numerics_fingerprint(prior) == numerics_fingerprint(base)


# ---------------------------------------------------------------------------
# Table persistence + registry.
# ---------------------------------------------------------------------------


def test_table_roundtrip_and_bucketing(tmp_path):
    t = TuningTable(device="test")
    plan = ExecPlan("kmm2", 12, backend="pallas", block_m=32, block_n=64,
                    block_k=32, combine_int32=False)
    key = t.put("pallas", (60, 100, 60), 12, plan, us=12.5)
    assert key == key_for("pallas", (64, 128, 64), 12)   # pow2 buckets
    path = tmp_path / "t.json"
    t.save(path)
    t2 = TuningTable.load(path)
    # any shape in the same bucket hits the entry
    got = t2.lookup("pallas", (57, 127, 33), 12)
    assert got is not None and got.tiles == (32, 64, 32)
    assert got.source == "table" and got.w == 12
    assert t2.lookup("pallas", (60, 100, 60), 8) is None
    assert t2.lookup("xla", (60, 100, 60), 12) is None
    # malformed entries read as missing, never crash
    doc = json.loads(path.read_text())
    doc["entries"][key_for("pallas", (8, 8, 8), 8)] = {"variant": 3}
    path.write_text(json.dumps(doc))
    assert TuningTable.load(path).lookup("pallas", (8, 8, 8), 8) is None


def test_use_table_scoped_install(tmp_path):
    t = TuningTable()
    before = get_active_table()
    with use_table(t) as active:
        assert active is t and get_active_table() is t
        with use_table(None):
            assert get_active_table() is None
        assert get_active_table() is t
    assert get_active_table() is before


# ---------------------------------------------------------------------------
# Acceptance: select_plan never violates headroom; tables never change
# numerics.
# ---------------------------------------------------------------------------


def _hostile_table():
    """Entries that are individually invalid or numerics-changing."""
    t = TuningTable()
    # int32 combine far past max_exact_k(14) = 8
    t.put("pallas", (64, 128, 64), 14,
          ExecPlan("kmm2", 14, backend="pallas", block_m=32, block_n=32,
                   block_k=32, combine_int32=True))
    # xla_ref where the fused dot overflows int32
    t.put("xla", (64, 4096, 64), 14,
          ExecPlan("xla_ref", 14, combine_int32=True))
    # numerics-changing: mm2 instead of kmm2 on the fp32 path
    t.put("xla", SHAPE, 12, ExecPlan("mm2", 12, backend="xla", depth=1))
    # valid exact-class variant switch
    t.put("pallas", (64, 128, 64), 10,
          ExecPlan("mm2", 10, backend="pallas", block_m=64, block_n=64,
                   block_k=64, combine_int32=True))
    return t


def test_select_plan_never_returns_headroom_violator():
    with use_table(_hostile_table()):
        for shape, w, backend, exact in [
                ((64, 128, 64), 14, "pallas", False),
                ((64, 4096, 64), 14, "xla", False),
                ((64, 128, 64), 10, "pallas", True),
                (SHAPE, 12, "xla", False)]:
            plan = select_plan(shape, w, backend=backend, exact=exact)
            if plan.variant in ("kmm2", "mm2", "mm1"):
                assert space.validate(plan, shape) is None, (shape, w, plan)
            if plan.combine_int32:
                assert max_exact_k(w) >= shape[1]
        # an exact request that cannot satisfy the headroom bound is refused
        # at the API boundary, before any plan (table or analytic) runs
        a = jnp.zeros((64, 128), jnp.int32)
        b = jnp.zeros((128, 64), jnp.int32)
        with pytest.raises(ValueError, match="max exact K"):
            ops.int_gemm(a, b, w=14, backend="pallas", exact=True)


def test_quantized_matmul_bit_identical_with_table():
    """A tuning table may change tiles/variant, never numerics: quantized
    matmul outputs are bit-identical with and without the table installed."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, 32)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    xb = jnp.asarray(rng.standard_normal((2, 8, 32)), jnp.float32)
    wb = jnp.asarray(rng.standard_normal((2, 32, 8)), jnp.float32)
    for w_bits in (8, 12, 16):
        base = np.asarray(quantized_matmul(x, wm, w_bits))
        base_b = np.asarray(quantized_matmul_batched(xb, wb, w_bits))
        with use_table(_hostile_table()):
            tuned = np.asarray(quantized_matmul(x, wm, w_bits))
            tuned_b = np.asarray(quantized_matmul_batched(xb, wb, w_bits))
        np.testing.assert_array_equal(base, tuned)
        np.testing.assert_array_equal(base_b, tuned_b)


def test_qmatmul_bit_identical_large_k_prior_path():
    """fp32 addition is exact below 2**24, so small-K identity tests cannot
    see numerics drift.  At w=8, K=2048 the accumulators pass 2**24; the
    exact-class guarantee in _int_dot (every exact-class plan — here the
    prior picks ffip — executes as the fused int32 dot) must keep the
    output bit-identical in this regime too, by construction rather than
    by rounding coincidence."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((2, 2048)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((2048, 64)), jnp.float32)
    base = np.asarray(quantized_matmul(x, wm, 8))
    with use_table(TuningTable()):       # active but empty -> prior path
        prior = np.asarray(quantized_matmul(x, wm, 8))
    with use_table(_hostile_table()):
        hostile = np.asarray(quantized_matmul(x, wm, 8))
    np.testing.assert_array_equal(base, prior)
    np.testing.assert_array_equal(base, hostile)


def test_int_gemm_pallas_fp32_table_tiles_preserve_k_padding():
    """fp32-Pallas accumulators depend on the padded K (zero-padded rows
    contribute centered digits and the z*z*kp correction; the cancellation
    is exact in real arithmetic but not guaranteed in fp32 past 2**24), so
    a same-fingerprint table entry is honored only when its block_k implies
    the analytic default's padded K; otherwise the table is ignored."""
    w, shape = 12, (8, 5000, 8)          # accumulators ~2e7 > 2**24
    rng = np.random.default_rng(13)
    a = jnp.asarray(rng.integers(-2048, 2048, (shape[0], shape[1])),
                    jnp.int32)
    b = jnp.asarray(rng.integers(-2048, 2048, (shape[1], shape[2])),
                    jnp.int32)
    base = np.asarray(ops.int_gemm(a, b, w=w, backend="pallas"))

    def table_with(bk):
        t = TuningTable()
        t.put("pallas", shape, w,
              ExecPlan("kmm2", w, backend="pallas", block_m=32, block_n=32,
                       block_k=bk, combine_int32=False))
        return t

    # block_k=128: padded K 5120 == the default 256-tile padding -> adopted
    with use_table(table_with(128)):
        plan = select_plan(shape, w, backend="pallas")
        assert plan.block_k == 128 and plan.source == "table"
        same_pad = np.asarray(ops.int_gemm(a, b, w=w, backend="pallas"))
    # block_k=64: padded K 5056 != 5120 -> table ignored, analytic plan
    with use_table(table_with(64)):
        plan = select_plan(shape, w, backend="pallas")
        assert plan.block_k == 256 and plan.source == "analytic"
        diff_pad = np.asarray(ops.int_gemm(a, b, w=w, backend="pallas"))
    np.testing.assert_array_equal(base, same_pad)
    np.testing.assert_array_equal(base, diff_pad)


def test_int_gemm_exact_bit_identical_under_variant_switch():
    """Exact-int plans are interchangeable: a table switching KMM2 -> MM2
    (+ tiles) on the exact pallas path must not move a single bit."""
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.integers(-512, 512, (64, 128)), jnp.int32)
    b = jnp.asarray(rng.integers(-512, 512, (128, 64)), jnp.int32)
    base = np.asarray(ops.int_gemm(a, b, w=10, backend="pallas", exact=True))
    with use_table(_hostile_table()):
        plan = select_plan((64, 128, 64), 10, backend="pallas", exact=True)
        assert plan.source == "table" and plan.variant == "mm2"
        tuned = np.asarray(ops.int_gemm(a, b, w=10, backend="pallas",
                                        exact=True))
    np.testing.assert_array_equal(base, tuned)
    np.testing.assert_array_equal(base.astype(np.int64),
                                  ref_int_gemm_i64(np.asarray(a),
                                                   np.asarray(b)))


# ---------------------------------------------------------------------------
# Runner + end-to-end registry flow.
# ---------------------------------------------------------------------------


def test_tune_shape_and_registry_flow(tmp_path):
    res = runner.tune_shape(SHAPE, 8, backend="pallas", iters=1,
                            tile_choices=(32,))
    assert res.winner is not None
    assert space.validate(res.winner, SHAPE) is None
    assert all(m.ok for m in res.measurements if m.us < float("inf"))
    t = TuningTable(device="test")
    t.put("pallas", SHAPE, 8, res.winner, us=res.winner_us)
    path = tmp_path / "tuned.json"
    t.save(path)
    with use_table(str(path)):        # set_active_table accepts a path
        plan = select_plan(SHAPE, 8, backend="pallas")
        assert plan.source in ("table", "table+tiles")
        assert plan.tiles == res.winner.tiles


def test_bench_json_emission(tmp_path):
    """benchmarks/run.py persists machine-readable BENCH_<group>.json."""
    from benchmarks.run import write_bench_json

    rows = [{"bench": "serve", "name": "serve/x/slots4", "us_per_call": 9.1,
             "tokens_per_s": 123.4, "ttft_mean_ms": 5.6}]
    checks = [("claim", True, "detail")]
    path = write_bench_json("serve", rows, checks, str(tmp_path))
    doc = json.loads(open(path).read())
    assert path.endswith("BENCH_serve.json")
    assert doc["rows"][0]["tokens_per_s"] == 123.4
    assert doc["checks"] == [{"claim": "claim", "ok": True,
                              "detail": "detail"}]


def test_runner_rejects_wrong_candidates(monkeypatch):
    """The correctness gate actually gates: a broken plan never wins."""
    a, b = runner.make_operands(SHAPE, 8, seed=0)
    good = ExecPlan("mm1", 8, backend="pallas", block_m=32, block_n=32,
                    block_k=32, combine_int32=True)
    ok, _ = runner.check_plan(good, a, b)
    assert ok
    bad = ExecPlan("mm1", 8, backend="pallas", block_m=32, block_n=32,
                   block_k=32, combine_int32=True)
    orig = ops.run_plan_jit

    def corrupt(x, y, plan, **kw):
        out = orig(x, y, plan, **kw)
        return out + 1 if plan is bad else out

    monkeypatch.setattr(runner.ops, "run_plan_jit", corrupt)
    ok, err = runner.check_plan(bad, a, b)
    assert not ok and "oracle" in err
