"""benchmarks/check_regression.py gate semantics: dropped rows and stale
--match tokens must FAIL, not silently leave the comparison."""
import json
import sys

sys.path.insert(0, ".")                      # benchmarks/ is not a package
from benchmarks import check_regression as cr  # noqa: E402


def _write(path, rows):
    path.write_text(json.dumps({"rows": rows}))
    return str(path)


def _row(name, val):
    return {"bench": "walltime", "name": name, "us_per_call": val}


def _full(strassen=0.7, fused=0.5, mm1=100.0):
    """One row per default --match family (int_gemm, fused_over_staged,
    strassen_ratio) so the stale-token check stays quiet."""
    return [_row("int_gemm_w8_mm1_1024", mm1),
            _row("fused_over_staged_time_ratio_x", fused),
            _row("strassen_ratio_kmm2_over_fused_w9_x", strassen)]


def test_ok_run_passes(tmp_path):
    base = _write(tmp_path / "base.json", _full())
    new = _write(tmp_path / "new.json", _full(strassen=0.71, mm1=101.0))
    assert cr.main(["--baseline", base, "--new", new]) == 0


def test_regressed_strassen_ratio_fails_under_default_match(tmp_path):
    """Only the strassen ratio moves — so this doubles as the proof that
    the DEFAULT --match set gates the strassen_ratio rows."""
    base = _write(tmp_path / "base.json", _full(strassen=0.7))
    new = _write(tmp_path / "new.json", _full(strassen=1.4))
    assert cr.main(["--baseline", base, "--new", new]) == 1


def test_dropped_row_fails(tmp_path):
    """A baseline row missing from the new run is a gate failure (a rename
    must update the baseline deliberately, not slip out of gating)."""
    base = _write(tmp_path / "base.json",
                  [_row("int_gemm_w8_mm1_1024", 100.0),
                   _row("int_gemm_w12_kmm2_1024", 300.0)])
    new = _write(tmp_path / "new.json",
                 [_row("int_gemm_w8_mm1_1024", 100.0)])
    assert cr.main(["--baseline", base, "--new", new,
                    "--match", "int_gemm"]) == 1


def test_stale_match_token_fails(tmp_path):
    """A --match token matching NO rows in either file fails: a whole row
    family renamed + baseline regenerated in one change would otherwise
    leave the gate while the remaining tokens kept it green."""
    rows = [_row("int_gemm_w8_mm1_1024", 100.0),
            _row("fused_over_staged_time_ratio_x", 0.5)]
    base = _write(tmp_path / "base.json", rows)
    new = _write(tmp_path / "new.json", rows)
    # default --match includes strassen_ratio, absent from both files
    assert cr.main(["--baseline", base, "--new", new]) == 1
    # explicitly matching only the present families passes
    assert cr.main(["--baseline", base, "--new", new,
                    "--match", "int_gemm", "fused_over_staged"]) == 0
