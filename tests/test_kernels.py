"""Pallas kernel tests: shape/dtype sweeps against the pure-jnp oracle
(interpret=True executes the kernel body on CPU)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.ops import int_gemm, quantize_symmetric
from repro.kernels.ref import (
    ref_digit_planes, ref_int_gemm_i64, ref_kmm2_planes, ref_mm2_planes,
)
from repro.kernels.kmm_gemm import kmm2_gemm_planes
from repro.kernels.mm2_gemm import mm2_gemm_planes
from repro.kernels.mm1_gemm import mm1_gemm
from repro.kernels.ffip import ffip_gemm_literal, ffip_mults


def _rand_w(rng, w, shape):
    lim = 2 ** (w - 1)
    return rng.integers(-lim, lim, size=shape).astype(np.int32)


SHAPES = [(64, 64, 64), (128, 256, 128), (130, 70, 50), (1, 64, 1)]


@pytest.mark.parametrize("w", [8, 9, 12, 14, 15, 16])
@pytest.mark.parametrize("mkn", SHAPES)
def test_int_gemm_pallas_vs_oracle(w, mkn):
    m, k, n = mkn
    rng = np.random.default_rng(w * 1000 + m)
    a = _rand_w(rng, w, (m, k))
    b = _rand_w(rng, w, (k, n))
    ref = ref_int_gemm_i64(a, b).astype(np.float64)
    out = np.asarray(int_gemm(jnp.array(a), jnp.array(b), w=w,
                              backend="pallas", block_m=64, block_n=64,
                              block_k=64))
    denom = max(np.abs(ref).max(), 1.0)
    assert np.abs(out - ref).max() / denom < 1e-6, (w, mkn)


@pytest.mark.parametrize("w", [8, 12, 16])
def test_int_gemm_xla_matches_pallas(w):
    rng = np.random.default_rng(w)
    a = _rand_w(rng, w, (96, 192))
    b = _rand_w(rng, w, (192, 64))
    xla = np.asarray(int_gemm(jnp.array(a), jnp.array(b), w=w, backend="xla"))
    pal = np.asarray(int_gemm(jnp.array(a), jnp.array(b), w=w,
                              backend="pallas", block_m=32, block_n=32,
                              block_k=64))
    # normalized error: fp32 combine rounds intermediates ~2^w larger than
    # the output (digit-recombination cancellation), so compare against the
    # output scale, not elementwise.
    denom = max(np.abs(xla).max(), 1.0)
    assert np.abs(xla - pal).max() / denom < 1e-5


def test_exact_int32_path():
    rng = np.random.default_rng(7)
    w, k = 10, 128  # within max_exact_k(10) = 2048
    a = _rand_w(rng, w, (64, k))
    b = _rand_w(rng, w, (k, 64))
    out = np.asarray(int_gemm(jnp.array(a), jnp.array(b), w=w,
                              backend="pallas", exact=True,
                              block_m=64, block_n=64, block_k=64))
    np.testing.assert_array_equal(out.astype(np.int64), ref_int_gemm_i64(a, b))


def test_exact_refuses_overflow():
    a = jnp.zeros((8, 4096), jnp.int32)
    b = jnp.zeros((4096, 8), jnp.int32)
    with pytest.raises(ValueError):
        int_gemm(a, b, w=14, exact=True)


class TestDigitPlanes:
    @pytest.mark.parametrize("w", [9, 12, 14, 16])
    def test_planes_reconstruct(self, w):
        rng = np.random.default_rng(w)
        x = _rand_w(rng, w, (256,))
        hi, lo, h, z = ref_digit_planes(jnp.array(x), w)
        recon = (np.asarray(hi).astype(np.int64) << h) + np.asarray(lo) + z
        np.testing.assert_array_equal(recon, x)
        # all planes must be s8-representable (MXU operands)
        for p in (hi, lo):
            assert np.asarray(p).min() >= -128 and np.asarray(p).max() <= 127

    def test_as_plane_fits_s8_up_to_w14(self):
        """The paper's 2m-2 bound: A1+A0c fits s8 for w<=14, not w=16."""
        for w, fits in [(12, True), (14, True), (16, False)]:
            lim = 2 ** (w - 1)
            x = jnp.arange(-lim, lim, max(1, lim // 1024), dtype=jnp.int32)
            hi, lo, h, z = ref_digit_planes(x, w)
            s = np.asarray(hi).astype(np.int32) + np.asarray(lo)
            ok = s.min() >= -128 and s.max() <= 127
            assert ok == fits, (w, s.min(), s.max())


@settings(max_examples=20, deadline=None)
@given(w=st.integers(9, 14), bm=st.sampled_from([16, 32]),
       seed=st.integers(0, 2**31 - 1))
def test_property_kmm2_kernel_tiling_invariance(w, bm, seed):
    """Kernel output must not depend on block shape (tiling correctness)."""
    rng = np.random.default_rng(seed)
    a = _rand_w(rng, w, (64, 128))
    b = _rand_w(rng, w, (128, 64))
    h = -(-w // 2)
    from repro.kernels.ops import _planes
    a1, a0, _ = _planes(jnp.array(a), h)
    b1, b0, _ = _planes(jnp.array(b), h)
    ref = np.asarray(ref_kmm2_planes(a1, a0, b1, b0, h))
    out = np.asarray(kmm2_gemm_planes(a1, a0, b1, b0, h=h, block_m=bm,
                                      block_n=32, block_k=32))
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_mm2_kernel_vs_planes_oracle():
    rng = np.random.default_rng(0)
    w, h = 16, 8
    a = _rand_w(rng, w, (64, 128))
    b = _rand_w(rng, w, (128, 64))
    from repro.kernels.ops import _planes
    a1, a0, _ = _planes(jnp.array(a), h)
    b1, b0, _ = _planes(jnp.array(b), h)
    out = np.asarray(mm2_gemm_planes(a1, a0, b1, b0, h=h, block_m=32,
                                     block_n=32, block_k=64))
    ref = np.asarray(ref_mm2_planes(a1, a0, b1, b0, h))
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_mm1_kernel_exact():
    rng = np.random.default_rng(1)
    a = rng.integers(-128, 128, size=(128, 256)).astype(np.int8)
    b = rng.integers(-128, 128, size=(256, 128)).astype(np.int8)
    out = np.asarray(mm1_gemm(jnp.array(a), jnp.array(b), block_m=64,
                              block_n=64, block_k=64))
    np.testing.assert_array_equal(out.astype(np.int64), ref_int_gemm_i64(a, b))


class TestFFIP:
    def test_literal_matches_matmul(self):
        rng = np.random.default_rng(2)
        a = rng.integers(-64, 64, size=(10, 24)).astype(np.int32)
        b = rng.integers(-64, 64, size=(24, 8)).astype(np.int32)
        out = np.asarray(ffip_gemm_literal(jnp.array(a), jnp.array(b)))
        np.testing.assert_array_equal(out.astype(np.int64),
                                      ref_int_gemm_i64(a, b))

    def test_halves_multiplications(self):
        m, k, n = 64, 128, 64
        conv = m * n * k
        assert ffip_mults(m, k, n) / conv == pytest.approx(0.5, abs=0.05)


def test_quantize_symmetric_roundtrip():
    rng = np.random.default_rng(5)
    x = jnp.array(rng.standard_normal((64, 64)), jnp.float32)
    q, scale = quantize_symmetric(x, 8)
    err = np.abs(np.asarray(q) * np.asarray(scale) - np.asarray(x)).max()
    assert err <= np.asarray(scale) * 0.5 + 1e-7
