"""End-to-end behaviour tests for the paper's system.

The headline system claims, executed end-to-end:
  1. quantized serving through the KMM engine produces the same generations
     as MM2 (algebraic equivalence of the 3-product decomposition) while
     spending 3/4 of the digit-product MXU passes;
  2. the precision-scalable policy routes per-layer bitwidths to the modes
     the paper prescribes;
  3. the serve engine runs batched requests with prefill+decode.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.quant.policy import QuantConfig
from repro.serve.engine import Engine, Request


def _gen(cfg, seed=0, n=4, max_new=8):
    params = lm.init_params(jax.random.PRNGKey(42), cfg)
    engine = Engine(cfg, params, max_seq=64, batch_size=n)
    rng = np.random.default_rng(seed)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, size=8)),
                    max_new_tokens=max_new) for _ in range(n)]
    engine.generate(reqs)
    return [r.generated for r in reqs]


@pytest.mark.slow
def test_serve_greedy_deterministic():
    cfg = get_config("llama3.2-1b", smoke=True, quant="w12")
    assert _gen(cfg) == _gen(cfg)


def test_kmm_and_mm2_serving_agree():
    """KMM2 vs forced-MM2 at the same bitwidth: same algebra, same tokens."""
    base = get_config("llama3.2-1b", smoke=True)
    kmm = base.with_quant(QuantConfig(enabled=True, default_bits=12))
    mm2 = base.with_quant(QuantConfig(enabled=True, default_bits=12,
                                      force_mode="mm2"))
    assert _gen(kmm) == _gen(mm2)


@pytest.mark.slow
def test_quantized_close_to_fp_serving():
    base = get_config("llama3.2-1b", smoke=True)
    fp = _gen(base)
    q12 = _gen(base.with_quant(QuantConfig(enabled=True, default_bits=12)))
    # 12-bit quantization shouldn't derail most greedy tokens on a smoke model
    agree = np.mean([a == b for fa, fb in zip(fp, q12)
                     for a, b in zip(fa, fb)])
    assert agree > 0.5, (fp, q12)


def test_mixed_policy_modes_exercised():
    cfg = get_config("gemma-2b", smoke=True, quant="mixed")
    q = cfg.quant
    modes = {q.plan_for(n).mode.value
             for n in ("blk0.mlp.wi", "lm_head", "blk0.attn.wq")}
    assert "mm1" in modes and "kmm2" in modes


def test_serve_temperature_sampling_runs():
    cfg = get_config("llama3.2-1b", smoke=True, quant="w8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_seq=64, batch_size=2)
    reqs = [Request(prompt=[5, 6, 7], max_new_tokens=4, temperature=0.9),
            Request(prompt=[8, 9], max_new_tokens=6, temperature=0.0)]
    stats = engine.generate(reqs)
    assert len(reqs[0].generated) == 4
    assert len(reqs[1].generated) == 6
    # continuous batching: first tokens come from prefill, then the engine
    # only steps while the longest request is live (5 steps, not max*2)
    assert stats.decode_steps == 5
    assert stats.generated_tokens == 10
