"""ExecContext API tests: the unified execution-context bundle and the
deprecation shims that keep the scattered legacy kwargs working.

Covers (PR-6 acceptance): old kwargs == new context bit-for-bit on every
former ``backend=``/``quant_backend=``/``force_mode=`` entry point, exactly
one ``DeprecationWarning`` per legacy call (listing the kwargs), and
``TypeError`` when context and legacy kwargs are mixed.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.context import ExecContext, resolve_context
from repro.core.dispatch import select_plan
from repro.kernels import ops
from repro.quant.qmatmul import (
    prequant_matmul, quantized_matmul, quantized_matmul_batched,
)


@pytest.fixture(scope="module")
def operands():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 64), jnp.float32)
    wm = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    return x, wm


def _one_deprecation(rec):
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, [str(w.message) for w in rec]
    return str(deps[0].message)


# ---------------------------------------------------------------------------
# ExecContext itself.
# ---------------------------------------------------------------------------


def test_context_validates_fields():
    with pytest.raises(ValueError, match="backend"):
        ExecContext(backend="cuda")
    with pytest.raises(ValueError, match="force_mode"):
        ExecContext(force_mode="kmm3")


def test_context_hashable_and_table_excluded_from_eq():
    a = ExecContext(backend="pallas")
    b = ExecContext(backend="pallas", tuning_table="/some/table.json")
    # tables are numerics-pinned: contexts differing only in table are
    # interchangeable as jit static args / cache keys
    assert a == b and hash(a) == hash(b)
    assert a != ExecContext(backend="xla")
    jax.jit(lambda x: x + 1, static_argnames=())  # smoke: hashability used
    d = {a: 1}
    assert d[b] == 1


def test_context_replace():
    ctx = ExecContext(backend="pallas").replace(force_mode="mm2")
    assert ctx.backend == "pallas" and ctx.force_mode == "mm2"


def test_resolve_context_passthrough_and_defaults():
    ctx = ExecContext(backend="pallas")
    assert resolve_context(ctx, what="t") is ctx
    assert resolve_context(None, what="t") == ExecContext()
    seeded = ExecContext(backend="pallas", force_mode="mm2")
    assert resolve_context(None, what="t", _defaults=seeded) is seeded


def test_resolve_context_legacy_folds_and_warns_once():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ctx = resolve_context(None, what="thing", backend="pallas",
                              tuning_table="tbl.json")
    msg = _one_deprecation(rec)
    assert "thing" in msg and "backend" in msg and "tuning_table" in msg
    assert ctx.backend == "pallas" and ctx.tuning_table == "tbl.json"


def test_resolve_context_rejects_mixed():
    with pytest.raises(TypeError, match="not both"):
        resolve_context(ExecContext(), what="t", backend="pallas")


# ---------------------------------------------------------------------------
# Shim equivalence: legacy kwargs == context, warning raised.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_quantized_matmul_shim_equivalence(operands, backend):
    x, wm = operands
    new = quantized_matmul(x, wm, 12, context=ExecContext(backend=backend))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = quantized_matmul(x, wm, 12, 8, "auto", backend)
    assert "quantized_matmul" in _one_deprecation(rec)
    assert np.array_equal(np.asarray(new), np.asarray(old))


def test_quantized_matmul_mixed_raises(operands):
    x, wm = operands
    with pytest.raises(TypeError, match="not both"):
        quantized_matmul(x, wm, 8, backend="xla", context=ExecContext())


def test_quantized_matmul_batched_shim_equivalence(operands):
    xb = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 64), jnp.float32)
    wb = jax.random.normal(jax.random.PRNGKey(3), (2, 64, 32), jnp.float32)
    new = quantized_matmul_batched(xb, wb, 8,
                                   context=ExecContext(backend="pallas"))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = quantized_matmul_batched(xb, wb, 8, backend="pallas")
    _one_deprecation(rec)
    assert np.array_equal(np.asarray(new), np.asarray(old))


def test_prequant_matmul_shim_equivalence(operands):
    from repro.quant.policy import POLICY_W8
    from repro.quant.prequant import prequantize

    x, wm = operands
    rec_w = prequantize({"wi": wm}, POLICY_W8)["wi"]
    new = prequant_matmul(x, rec_w, 8, context=ExecContext(backend="pallas"))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = prequant_matmul(x, rec_w, 8, backend="pallas")
    _one_deprecation(rec)
    assert np.array_equal(np.asarray(new), np.asarray(old))


def test_force_mode_via_context(operands):
    x, wm = operands
    new = quantized_matmul(x, wm, 12, context=ExecContext(force_mode="mm2"))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = quantized_matmul(x, wm, 12, 8, "mm2")
    _one_deprecation(rec)
    assert np.array_equal(np.asarray(new), np.asarray(old))


def test_int_gemm_context(operands):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-100, 100, (16, 32)), jnp.int32)
    b = jnp.asarray(rng.integers(-100, 100, (32, 16)), jnp.int32)
    via_ctx = ops.int_gemm(a, b, w=8,
                           context=ExecContext(backend="pallas"))
    via_kwarg = ops.int_gemm(a, b, w=8, backend="pallas")
    assert np.array_equal(np.asarray(via_ctx), np.asarray(via_kwarg))


def test_select_plan_context(monkeypatch):
    shape = (128, 1024, 128)
    via_kwarg = select_plan(shape, 12, backend="pallas")
    via_ctx = select_plan(shape, 12, context=ExecContext(backend="pallas"))
    assert via_ctx == via_kwarg
    # context backend wins over the legacy kwarg default
    assert select_plan(shape, 12,
                       context=ExecContext(backend="xla")).backend == "xla"


def test_context_tuning_table_activate(tmp_path):
    """context.tuning_table routes through select_plan without mutating the
    global registry outside activate()."""
    from repro.core.dispatch import ExecPlan
    from repro.tune.table import TuningTable, get_active_table

    table = TuningTable(device="cpu/test")
    table.put("pallas", (128, 1024, 128), 12,
              ExecPlan("fused", 12, backend="pallas", block_m=32,
                       block_n=32, block_k=512))
    ctx = ExecContext(backend="pallas", tuning_table=table)
    plan = select_plan((128, 1024, 128), 12, context=ctx)
    assert plan.source == "table" and plan.block_m == 32
    assert get_active_table() is None   # registry untouched
    with ctx.activate():
        assert get_active_table() is table
    assert get_active_table() is None


# ---------------------------------------------------------------------------
# Engine shim.
# ---------------------------------------------------------------------------


def test_engine_shim_equivalence():
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import Engine, Request

    cfg = get_config("llama3.2-1b", smoke=True, quant="w8").scaled_down(
        d_model=64, d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
        head_dim=16)
    params = lm.init_params(jax.random.PRNGKey(7), cfg)

    def run(**kw):
        eng = Engine(cfg, params, max_seq=32, batch_size=2, rng_seed=3, **kw)
        reqs = [Request(prompt=[5, 6, 7], max_new_tokens=3),
                Request(prompt=[9] * 6, max_new_tokens=2, temperature=0.7)]
        eng.generate(reqs)
        return eng, [r.generated for r in reqs]

    eng_new, toks_new = run(context=ExecContext(backend="pallas"))
    assert eng_new.context.backend == "pallas"
    assert eng_new.cfg.quant.backend == "pallas"
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        _, toks_old = run(quant_backend="pallas")
    assert "Engine" in _one_deprecation(rec)
    assert toks_new == toks_old
    with pytest.raises(TypeError, match="not both"):
        run(quant_backend="pallas", context=ExecContext())


def test_train_config_tuning_table_deprecated(tmp_path):
    """TrainConfig.tuning_table folds into a context with a warning."""
    from repro.train.loop import TrainConfig

    tc = TrainConfig(tuning_table=None, context=None)
    assert resolve_context(tc.context, what="TrainConfig",
                           tuning_table=tc.tuning_table or None) \
        == ExecContext()
    tc2 = TrainConfig(tuning_table="tbl.json")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ctx = resolve_context(tc2.context, what="TrainConfig",
                              tuning_table=tc2.tuning_table or None)
    _one_deprecation(rec)
    assert ctx.tuning_table == "tbl.json"
