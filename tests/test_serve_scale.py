"""Serve-scale smoke: an 8-slot engine with chunked prefill + prefix
sharing serves a mixed workload token-identically to sequential 1-slot
generation, while actually exercising the scaled machinery (bucketed
decode widths, interleaved prefill, snapshot restores).

This is the CI ``serve-scale`` gate: it fails if slot scaling, chunking or
prefix sharing ever drifts from the sequential reference.  Tests must
drive the engine through its public API — a repo lint keeps pokes at the
old monolith's private slot array out of the test suite (scheduling state
now lives behind ``engine.scheduler`` / ``engine.pool``).
"""
import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Engine, Request


def _mk_requests(prompts):
    return [Request(prompt=list(p), max_new_tokens=4 + (i % 3),
                    temperature=0.9 if i % 2 else 0.0,
                    stop_tokens=(7,) if i % 3 == 0 else ())
            for i, p in enumerate(prompts)]


def test_scaled_engine_matches_sequential():
    cfg = get_config("llama3.2-1b", smoke=True).scaled_down(
        d_model=64, d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
        head_dim=16)
    params = lm.init_params(jax.random.PRNGKey(7), cfg)
    rng = np.random.default_rng(3)
    shared = list(rng.integers(1, 250, size=16))
    prompts = [shared + list(rng.integers(1, 250, size=int(k)))
               for k in rng.integers(2, 20, size=12)]

    # sequential reference: one request at a time, one slot, no chunking
    seq_eng = Engine(cfg, params, max_seq=48, batch_size=1)
    ref = []
    for p in _mk_requests(prompts):
        seq_eng.generate([p])
        ref.append(p.generated)

    eng = Engine(cfg, params, max_seq=48, batch_size=8, prefill_chunk=8,
                 prefix_cache=True)
    reqs = _mk_requests(prompts)
    stats = eng.generate(reqs)
    assert [r.generated for r in reqs] == ref

    # the scaled path really ran scaled: multiple live slots per decode
    # step on average, and more than one decode-bucket width traced
    assert stats.occupancy_pct > 0
    assert stats.occupancy_sum > stats.decode_steps / 8   # > 1 live slot avg
    nt = eng.n_traces()["decode"]
    assert nt == -1 or nt >= 2, eng.n_traces()
    # prefix sharing engaged on the common prefix
    assert eng.prefix.stats()["hits"] >= 1, eng.prefix.stats()
    # every slot drained: no leaked slots or pending work
    assert eng.num_active == 0 and eng.num_pending == 0
    assert stats.generated_tokens == sum(len(r) for r in ref)


def test_warm_pretraces_all_widths():
    cfg = get_config("llama3.2-1b", smoke=True).scaled_down(
        d_model=64, d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
        head_dim=16)
    params = lm.init_params(jax.random.PRNGKey(7), cfg)
    eng = Engine(cfg, params, max_seq=32, batch_size=4, prefill_chunk=8)
    eng.warm()
    warm = eng.n_traces()
    if warm["decode"] == -1:
        pytest.skip("jit cache size not exposed on this jax")
    assert warm["decode"] == len(eng.scheduler.decode_widths)
    rng = np.random.default_rng(0)
    reqs = _mk_requests([list(rng.integers(1, 250, size=n))
                         for n in (3, 9, 14, 5, 11)])
    eng.generate(reqs)
    assert eng.n_traces() == warm        # steady state: zero retraces
