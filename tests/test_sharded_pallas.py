"""Sharded Pallas kernel tests (PR-6 tentpole acceptance), subprocess-
isolated with 8 forced host devices like tests/test_sharded.py.

Covers:
  * capability negotiation unit behaviour (axes picked / reasons given);
  * kernel-level bit-identity: the fused KMM2 kernel shard-mapped over a
    2x4 mesh == the unsharded fused kernel, bit-for-bit (fp32 w12 class
    AND exact w8 class vs the int64 oracle);
  * K-sharded exact-int split: int32 partials psum'd over the model axis
    == the oracle, and the fp32 class refuses K-sharding;
  * engine token-identity: quantized serve with backend="pallas" on the
    2x4 mesh == the same engine unsharded (and the XLA backend for w8);
  * capability-negotiation fallback: a (1, 8) mesh with a d_ff the model
    axis cannot tile downgrades the MLP GEMMs to XLA (logged) while the
    rest stay shard-mapped — tokens still identical to unsharded.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import logging
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.context import ExecContext
from repro.core.dispatch import GemmShardSpec, select_plan
from repro.dist import shard_gemm as sg
from repro.kernels import ops
from repro.kernels.ref import ref_int_gemm_i64
from repro.launch.mesh import make_mesh
from repro.quant.qmatmul import quantized_matmul, quantized_matmul_batched

mesh = make_mesh((2, 4))
assert len(jax.devices()) == 8

# ---- negotiate: axes and reasons ------------------------------------------
spec, reason = sg.negotiate((32, 256, 1024), mesh)
assert spec == GemmShardSpec(m_axes=("data",), n_axes=("model",)), spec
assert sg.local_shape((32, 256, 1024), spec, mesh) == (16, 256, 256)
spec, reason = sg.negotiate((33, 256, 1025), mesh)   # neither axis divides
assert spec is None and "1025" in reason, (spec, reason)
spec, reason = sg.negotiate((33, 256, 1024), mesh)   # N-only sharding
assert spec == GemmShardSpec(n_axes=("model",)), spec
spec, reason = sg.negotiate((8, 64, 96), mesh, n_experts=8)
assert spec == GemmShardSpec(e_axes=("model",)), spec
spec, reason = sg.negotiate((8, 64, 96), mesh, n_experts=6)
assert spec is None and "expert" in reason, (spec, reason)
assert sg.negotiate((32, 256, 1024), None)[0] is None

# ---- kernel-level bit-identity: fp32 w12 class ----------------------------
rng = np.random.default_rng(0)
M, K, N = 32, 256, 1024
x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
wm = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
unsharded = quantized_matmul(x, wm, 12, context=ExecContext(backend="pallas"))
xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
ws = jax.device_put(wm, NamedSharding(mesh, P(None, "model")))
with mesh:
    sharded = quantized_matmul(xs, ws, 12,
                               context=ExecContext(backend="pallas",
                                                   mesh=mesh))
assert np.array_equal(np.asarray(sharded), np.asarray(unsharded)), \
    "sharded fused w12 != unsharded (fp32 class must be bit-exact)"

# ---- kernel-level bit-identity: exact w8 class vs int64 oracle ------------
a8 = jnp.asarray(rng.integers(-120, 120, (M, K)), jnp.int32)
b8 = jnp.asarray(rng.integers(-120, 120, (K, N)), jnp.int32)
plan8 = select_plan((M, K, N), 8, backend="pallas")
with mesh:
    out8 = sg.sharded_run_plan(a8, b8, plan=plan8, mesh=mesh)
oracle = ref_int_gemm_i64(np.asarray(a8), np.asarray(b8))
assert np.array_equal(np.asarray(out8).astype(np.int64), oracle), \
    "M/N-sharded exact w8 != int64 oracle"

# ---- K-sharded exact-int split (psum of int32 partials) -------------------
kspec = GemmShardSpec(m_axes=("data",), k_axes=("model",))
from dataclasses import replace
with mesh:
    outk = sg.sharded_run_plan(a8, b8, plan=replace(plan8, shard=kspec),
                               mesh=mesh)
assert np.array_equal(np.asarray(outk).astype(np.int64), oracle), \
    "K-sharded exact w8 != int64 oracle"
plan12 = select_plan((M, K, N), 12, backend="pallas")
if not plan12.is_exact_int:
    try:
        with mesh:
            sg.sharded_run_plan(a8, b8, plan=replace(plan12, shard=kspec),
                                mesh=mesh)
        raise AssertionError("fp32-combine plan accepted K-sharding")
    except ValueError as e:
        assert "exact-int" in str(e)

# ---- grouped expert GEMM under the mesh -----------------------------------
E, C = 8, 8
xb = jnp.asarray(rng.standard_normal((E, C, 64)), jnp.float32)
wb = jnp.asarray(rng.standard_normal((E, 64, 96)), jnp.float32)
unsharded_b = quantized_matmul_batched(xb, wb, 12,
                                       context=ExecContext(backend="pallas"))
with mesh:
    sharded_b = quantized_matmul_batched(
        xb, wb, 12, context=ExecContext(backend="pallas", mesh=mesh))
assert np.array_equal(np.asarray(sharded_b), np.asarray(unsharded_b)), \
    "expert-sharded grouped kernel != unsharded"

# ---- capability fallback logs a reason, computes via XLA ------------------
records = []
handler = logging.Handler()
handler.emit = lambda rec: records.append(rec.getMessage())
logging.getLogger("repro.dist").addHandler(handler)
logging.getLogger("repro.dist").setLevel(logging.INFO)
x_odd = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
w_odd = jnp.asarray(rng.standard_normal((K, 1025)), jnp.float32)
with mesh:
    out_odd = quantized_matmul(
        x_odd, w_odd, 12, context=ExecContext(backend="pallas", mesh=mesh))
ref_odd = quantized_matmul(x_odd, w_odd, 12)   # xla, default context
# M=32 divides data(2): negotiation shards M-only and the kernel still runs
np.testing.assert_allclose(np.asarray(out_odd), np.asarray(ref_odd),
                           rtol=1e-5, atol=1e-5)
# force a total fallback with an indivisible M too:
x_np = jnp.asarray(rng.standard_normal((33, K)), jnp.float32)
with mesh:
    out_np = quantized_matmul(
        x_np, w_odd, 12, context=ExecContext(backend="pallas", mesh=mesh))
assert any("falls back to XLA" in m for m in records), records
ref_np = quantized_matmul(x_np, w_odd, 12)
np.testing.assert_allclose(np.asarray(out_np), np.asarray(ref_np),
                           rtol=1e-5, atol=1e-5)

# ---- engine token-identity on the 2x4 mesh --------------------------------
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Engine, Request

cfg = get_config("llama3.2-1b", smoke=True, quant="w8").scaled_down(
    d_model=256, d_ff=1024, vocab_size=2048, n_heads=8,
    n_kv_heads=4, head_dim=32)
params = lm.init_params(jax.random.PRNGKey(0), cfg)

def serve(cfg, backend, mesh_arg):
    rng2 = np.random.default_rng(7)
    reqs = [Request(prompt=list(rng2.integers(1, cfg.vocab_size, size=int(n))),
                    max_new_tokens=int(m), temperature=t)
            for n, m, t in zip(rng2.integers(2, 9, size=6),
                               rng2.integers(1, 4, size=6),
                               (0.0, 0.8, 0.0, 0.7, 0.0, 0.9))]
    eng = Engine(cfg, params, max_seq=32, batch_size=8,
                 context=ExecContext(backend=backend, mesh=mesh_arg))
    eng.generate(reqs)
    nt = eng.n_traces()["decode"]
    assert nt == -1 or 1 <= nt <= 4, eng.n_traces()
    return [r.generated for r in reqs]

pallas_sharded = serve(cfg, "pallas", mesh)
pallas_unsharded = serve(cfg, "pallas", None)
assert pallas_sharded == pallas_unsharded, \
    (pallas_sharded, pallas_unsharded)
# w8 is in the exact-int class: XLA tokens must agree too
assert pallas_sharded == serve(cfg, "xla", mesh)

# ---- capability-negotiation fallback at the engine level ------------------
# (1, 8) mesh: no data parallelism, and a d_ff of 1020 is not divisible by
# the model axis -> the MLP wi/wg GEMMs must downgrade to XLA while the
# remaining GEMMs (N = 256 / padded vocab, both % 8 == 0) stay shard-mapped.
mesh18 = make_mesh((1, 8))
cfg_odd = cfg.scaled_down(d_ff=1020)
params = lm.init_params(jax.random.PRNGKey(0), cfg_odd)
records.clear()
mixed = serve(cfg_odd, "pallas", mesh18)
assert any("falls back to XLA" in m and "1020" in m for m in records), \
    records
assert mixed == serve(cfg_odd, "pallas", None), "fallback changed tokens"

print("SHARDED-PALLAS-OK")
"""


@pytest.mark.slow
def test_sharded_pallas_suite(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "sharded_pallas_check.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script), src],
                       capture_output=True, text=True, timeout=1800, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "SHARDED-PALLAS-OK" in r.stdout
