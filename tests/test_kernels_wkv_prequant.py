"""Coverage for the late-stage additions: WKV Pallas kernel, pre-quantized
weight storage, and the trip-count-aware HLO cost parser."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.wkv_gemm import hbm_traffic_model, wkv_apply, wkv_reference


class TestWkvKernel:
    @pytest.mark.parametrize("bh,s,d,chunk", [(4, 64, 16, 16), (2, 33, 8, 32),
                                              (8, 128, 64, 64), (1, 7, 4, 4)])
    def test_matches_oracle(self, bh, s, d, chunk):
        rng = np.random.default_rng(bh * 100 + s)
        r = jnp.array(rng.standard_normal((bh, s, d)), jnp.float32) * 0.5
        k = jnp.array(rng.standard_normal((bh, s, d)), jnp.float32) * 0.5
        v = jnp.array(rng.standard_normal((bh, s, d)), jnp.float32) * 0.5
        w = jnp.array(rng.uniform(0.8, 0.999, (bh, s, d)), jnp.float32)
        u = jnp.array(rng.standard_normal((bh, d)), jnp.float32) * 0.1
        y_k = np.asarray(wkv_apply(r, k, v, w, u, chunk=chunk))
        y_r = np.asarray(wkv_reference(r, k, v, w, u))
        denom = max(np.abs(y_r).max(), 1e-6)
        assert np.abs(y_k - y_r).max() / denom < 1e-5

    def test_chunk_invariance(self):
        rng = np.random.default_rng(0)
        args = [jnp.array(rng.standard_normal((2, 32, 8)), jnp.float32) * 0.3
                for _ in range(3)]
        w = jnp.array(rng.uniform(0.9, 0.999, (2, 32, 8)), jnp.float32)
        u = jnp.array(rng.standard_normal((2, 8)), jnp.float32) * 0.1
        y8 = np.asarray(wkv_apply(*args[:3], w, u, chunk=8))
        y32 = np.asarray(wkv_apply(*args[:3], w, u, chunk=32))
        np.testing.assert_allclose(y8, y32, rtol=1e-6, atol=1e-6)

    def test_traffic_model_reduction(self):
        m = hbm_traffic_model(80, 32768, 64)
        assert m["reduction"] > 50  # state-in-VMEM is a large win


class TestPrequant:
    @pytest.mark.slow
    def test_prequant_matches_dynamic_path(self):
        from repro.configs import get_config
        from repro.models import lm
        from repro.quant.prequant import prequantize

        cfg = get_config("llama3.2-1b", smoke=True, quant="w12")
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        qparams = prequantize(params, cfg.quant)
        t = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                               cfg.vocab_size)
        c1 = lm.init_cache(cfg, 2, 32)
        c2 = lm.init_cache(cfg, 2, 32)
        l1, _, _ = lm.prefill(params, cfg, t, c1)
        l2, _, _ = lm.prefill(qparams, cfg, t, c2)
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32), atol=1e-4)

    def test_storage_dtypes(self):
        from repro.quant.prequant import prequantize
        from repro.quant.policy import QuantConfig

        params = {"blocks": {"pos0": {"mlp": {
            "wi": jnp.ones((32, 64), jnp.float32),
            "wo": jnp.ones((64, 32), jnp.float32)}}}}
        q8 = prequantize(params, QuantConfig(enabled=True, default_bits=8))
        assert q8["blocks"]["pos0"]["mlp"]["wi"]["q"].dtype == jnp.int8
        q12 = prequantize(params, QuantConfig(enabled=True, default_bits=12))
        assert q12["blocks"]["pos0"]["mlp"]["wi"]["q"].dtype == jnp.int16

    def test_non_weight_leaves_untouched(self):
        from repro.quant.prequant import prequantize
        from repro.quant.policy import QuantConfig

        params = {"ln_f": {"scale": jnp.ones((8,))},
                  "blocks": {"pos0": {"attn": {
                      "wq": jnp.ones((16, 16), jnp.float32)}}}}
        q = prequantize(params, QuantConfig(enabled=True, default_bits=8))
        assert isinstance(q["ln_f"]["scale"], jax.Array)
        assert isinstance(q["blocks"]["pos0"]["attn"]["wq"], dict)


class TestHloCostParser:
    def test_scan_trip_counts(self):
        from repro.launch.hlo_stats import parse_costs

        def f(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=10)
            def body2(c, _):
                def inner(c2, _):
                    return c2 @ w, None
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None
            out2, _ = jax.lax.scan(body2, out, None, length=5)
            return out2

        spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        txt = jax.jit(f).lower(spec, spec).compile().as_text()
        c = parse_costs(txt)
        assert c["flops"] == pytest.approx((10 + 15) * 2 * 64**3)

    def test_dot_general_batched_flops(self):
        from repro.launch.hlo_stats import parse_costs

        def f(a, b):
            return jnp.einsum("bik,bkj->bij", a, b)

        a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
        txt = jax.jit(f).lower(a, b).compile().as_text()
        c = parse_costs(txt)
        assert c["flops"] == pytest.approx(2 * 4 * 8 * 8 * 16)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 3))
def test_property_ef_compression_error_feedback_contracts(seed, steps):
    """Error feedback keeps compression unbiased: the residual after each
    round is bounded by one quantization step of the current magnitude."""
    from repro.dist.collectives import ef_compress

    rng = np.random.default_rng(seed)
    x = jnp.array(rng.standard_normal((64,)), jnp.float32)
    err = jnp.zeros((64,))
    for _ in range(steps):
        q, scale, err = ef_compress(x, err)
        assert float(jnp.abs(err).max()) <= float(scale) * 0.5 + 1e-7
        recon = q.astype(jnp.float32) * scale + err
        np.testing.assert_allclose(np.asarray(recon), np.asarray(x + 0 * err),
                                   atol=float(scale) + 1e-6)
