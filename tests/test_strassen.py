"""Tile-level Strassen composition (core/strassen.py + ISSUE 10).

Covers the composed exactness bound end to end: bit-exactness of both
strassen variants against the int64 oracle (odd shapes included),
brute-force K-bound / K-bound+1 boundary tests mirroring
tests/test_kmm_core.py's ``max_exact_k`` boundary test, pruned-space
membership, the fingerprint guarantee that a tuned table cannot move bits
by swapping strassen in or out of a numerics class, the shard-local bound
re-check, and the cost-prior tile-add charge.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.dispatch import (ExecPlan, analytic_plan,
                                 numerics_fingerprint, select_plan)
from repro.core.kmm import max_exact_k
from repro.core.strassen import (STRASSEN_VARIANTS, strassen_sub_plan,
                                 strassen_sub_shape)
from repro.kernels import ops
from repro.kernels.ref import ref_int_gemm_i64
from repro.quant.qmatmul import quantized_matmul
from repro.tune import space
from repro.tune.table import TuningTable, use_table


def _plan(variant, w, m=8, tiles=(32, 32, 32)):
    backend = "xla" if variant == "strassen" else "pallas"
    return ExecPlan(variant, w, m, backend=backend, block_m=tiles[0],
                    block_n=tiles[1], block_k=tiles[2], combine_int32=True,
                    depth=1)


# ---------------------------------------------------------------------------
# Bit-exactness vs the int64 oracle, odd shapes included.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w,m", [(4, 4), (9, 8), (12, 8)])
@pytest.mark.parametrize("shape", [(7, 33, 5), (16, 64, 16), (30, 50, 18)])
def test_strassen_bit_exact_vs_oracle(w, m, shape):
    """Both variants reproduce the int64 oracle bit-for-bit, including odd
    M/K/N (the even-padding contract) and the MM1-window sub-plans
    (w=4, m=4: sub w=5 > m exercises the fused depth-1 sub)."""
    rng = np.random.default_rng(w * 100 + shape[1])
    lim = 1 << (w - 1)
    a = rng.integers(-lim, lim, size=shape[:2], dtype=np.int32)
    b = rng.integers(-lim, lim, size=(shape[1], shape[2]), dtype=np.int32)
    oracle = ref_int_gemm_i64(a, b)
    for variant in STRASSEN_VARIANTS:
        plan = _plan(variant, w, m)
        assert space.validate(plan, shape) is None
        out = np.asarray(ops.run_plan_jit(jnp.asarray(a), jnp.asarray(b),
                                          plan))
        assert out.dtype == np.int32
        np.testing.assert_array_equal(out.astype(np.int64), oracle,
                                      err_msg=f"{variant} w={w} {shape}")
        mirror = np.asarray(ops.run_plan_jit(jnp.asarray(a), jnp.asarray(b),
                                             plan, use_ref_kernels=True))
        np.testing.assert_array_equal(mirror, out,
                                      err_msg=f"{variant} ref mirror")


def test_strassen_sub_plan_derivation():
    sk = strassen_sub_plan(_plan("strassen+kmm2", 9))
    assert sk.variant == "fused" and sk.w == 10 and sk.depth == 1
    assert sk.combine_int32 and sk.backend == "pallas"
    # MM1-window parent: the sub still fits the multiplier -> depth 0
    sk8 = strassen_sub_plan(_plan("strassen+kmm2", 7))
    assert sk8.depth == 0 and sk8.w == 8
    sx = strassen_sub_plan(_plan("strassen", 9))
    assert sx.backend == "xla" and sx.w == 10 and sx.combine_int32
    assert strassen_sub_shape((7, 33, 5)) == (4, 17, 3)
    with pytest.raises(ValueError):
        strassen_sub_plan(ExecPlan("fused", 9, backend="pallas"))


# ---------------------------------------------------------------------------
# Composed K bound: brute force at K-bound / K-bound + 1.
# ---------------------------------------------------------------------------


def test_strassen_k_bound_values():
    """B(w) = 2 * max_exact_k(w+1) = 2**(30-2w): one factor-of-4 from the
    (w+1)-bit pre-add growth, one factor-of-2 back from the half K."""
    assert space.strassen_k_bound(_plan("strassen+kmm2", 4, m=4)) == 1 << 22
    assert space.strassen_k_bound(_plan("strassen+kmm2", 8)) == 16384
    assert space.strassen_k_bound(_plan("strassen+kmm2", 9)) == 4096
    assert space.strassen_k_bound(_plan("strassen+kmm2", 12)) == 64
    # w = 15: max_exact_k(16) = 0 -> strassen is never exact
    assert space.strassen_k_bound(_plan("strassen+kmm2", 15)) == 0
    # plan_accum_k_bound exposes the same composed bound to the generic
    # padded-K callers (qmatmul, shard negotiation)
    assert space.plan_accum_k_bound(_plan("strassen+kmm2", 9)) == 4096


# (w, m, M=N, tiles): geometries where the boundary K executes in seconds
# under the interpreter.  w=4 needs m=4 so the sub w=5 leaves the MM1
# window; its bound K = 2**22 runs as 7 fused (32, 2**21, 32) sub-GEMMs.
_BOUNDARY = (
    (4, 4, 2, (32, 32, 65536)),
    (8, 8, 16, (32, 32, 2048)),
    (12, 8, 16, (32, 32, 32)),
)


@pytest.mark.parametrize("w,m,mn,tiles", _BOUNDARY)
def test_strassen_boundary_brute_force(w, m, mn, tiles):
    """At the composed bound K = 2**(30-2w): all-max unsigned w-bit
    operands are bit-exact and every Strassen sub-product provably fits
    int32; at K+1 ``validate`` rejects the plan.  Like ``max_exact_k``
    (ring arithmetic), the recombined OUTPUT can stay correct past the
    bound — the bound's claim is that no *intermediate* wraps — so the
    K+1 assertion is the pruning boundary, and tightness (a sub-product
    actually exceeding int31) is asserted at the undiluted even K+2 for
    w >= 10, mirroring the w >= 11 restriction of the max_exact_k
    boundary test."""
    plan = _plan("strassen+kmm2", w, m, tiles)
    k = space.strassen_k_bound(plan)
    assert k == 1 << (30 - 2 * w)
    assert space.validate(plan, (mn, k, mn)) is None
    reason = space.validate(plan, (mn, k + 1, mn))
    assert reason is not None and "strassen" in reason

    hi = (1 << w) - 1

    def worst_sub_products(kk):
        """Max |sub-product| over the 7 products, int64, worst operands."""
        ks = -(-kk // 2)
        return 4 * ks * hi * hi          # (A11+A22)(B11+B22), all-max

    assert worst_sub_products(k) < 2 ** 31        # the bound's whole claim
    if w >= 10:
        assert worst_sub_products(k + 2) >= 2 ** 31   # tight (undiluted)

    a = np.full((mn, k), hi, np.int32)
    b = np.full((k, mn), hi, np.int32)
    oracle = ref_int_gemm_i64(a, b)
    out = np.asarray(ops.run_plan_jit(jnp.asarray(a), jnp.asarray(b), plan))
    np.testing.assert_array_equal(out.astype(np.int64), oracle)


# ---------------------------------------------------------------------------
# Pruned-space membership + cost prior.
# ---------------------------------------------------------------------------


def test_pruned_space_membership():
    """Both variants survive where K fits the composed bound and vanish one
    K step past it (the CI tune-smoke job asserts the same)."""
    ok = [p.variant for p in space.pruned_space((64, 64, 64), 12,
                                                backend="pallas",
                                                tile_choices=(32, 64))]
    assert "strassen" in ok and "strassen+kmm2" in ok
    over = [p.variant for p in space.pruned_space((64, 128, 64), 12,
                                                  backend="pallas",
                                                  tile_choices=(32, 64))]
    assert "strassen" not in over and "strassen+kmm2" not in over
    # backend-independent variant rides the xla sweep too
    xla = [p.variant for p in space.candidates((64, 64, 64), 12,
                                               backend="xla")]
    assert "strassen" in xla and "strassen+kmm2" not in xla


def test_cost_prior_charges_strassen_tile_adds():
    """The prior charges Strassen's pre-add/combine plane traffic: on small
    shapes the adds dominate the saved eighth of multiplies and strassen
    must NOT look cheapest, while on the deep-K flagship geometry
    strassen+kmm2 must undercut the fused kernel (7 vs 8 equal-shape
    sub-products)."""
    small = (16, 32, 16)
    t = (32, 32, 32)
    assert space.cost_prior(_plan("strassen+kmm2", 12, tiles=t), small) > \
        space.cost_prior(ExecPlan("fused", 12, backend="pallas", block_m=32,
                                  block_n=32, block_k=32, depth=1), small)
    flag, ft = (256, 4096, 256), (128, 128, 2048)
    assert space.cost_prior(_plan("strassen+kmm2", 9, tiles=ft), flag) < \
        space.cost_prior(ExecPlan("fused", 9, backend="pallas", block_m=128,
                                  block_n=128, block_k=2048,
                                  combine_int32=True, depth=1), flag)
    # prior-only fallback never leaves the analytic numerics class for
    # strassen (fp32 base classes exclude it by fingerprint)
    for w in (8, 12):
        prior = space.prior_plan(small, w, backend="pallas")
        assert prior is not None
        assert prior.variant not in STRASSEN_VARIANTS


# ---------------------------------------------------------------------------
# Tables stay speed-only: swapping strassen in/out of a class moves no bit.
# ---------------------------------------------------------------------------


def test_strassen_fingerprint_is_exact_class():
    for variant in STRASSEN_VARIANTS:
        fp = numerics_fingerprint(_plan(variant, 9))
        assert fp == numerics_fingerprint(analytic_plan(9, backend="pallas",
                                                        exact=True))


def _strassen_table():
    """Hostile/opportunistic entries: strassen at an exact key (legal
    adoption), at an fp32-class key (must be refused), and at a key past
    the composed bound (must be validate-discarded)."""
    t = TuningTable()
    t.put("pallas", (64, 64, 64), 12, _plan("strassen+kmm2", 12))
    t.put("pallas", (64, 32, 16), 8, _plan("strassen+kmm2", 8))
    t.put("pallas", (64, 128, 64), 12, _plan("strassen+kmm2", 12))  # K>bound
    return t


def test_table_swapping_strassen_cannot_move_bits():
    rng = np.random.default_rng(21)
    a = jnp.asarray(rng.integers(-2048, 2048, (64, 64)), jnp.int32)
    b = jnp.asarray(rng.integers(-2048, 2048, (64, 64)), jnp.int32)
    # exact request: the table legally swaps strassen+kmm2 in (same
    # fingerprint class) and the output is bit-identical to tableless
    base = np.asarray(ops.int_gemm(a, b, w=12, backend="pallas", exact=True))
    with use_table(_strassen_table()):
        plan = select_plan((64, 64, 64), 12, backend="pallas", exact=True)
        assert plan.variant == "strassen+kmm2" and plan.source == "table"
        tuned = np.asarray(ops.int_gemm(a, b, w=12, backend="pallas",
                                        exact=True))
    np.testing.assert_array_equal(base, tuned)
    np.testing.assert_array_equal(
        base.astype(np.int64),
        ref_int_gemm_i64(np.asarray(a), np.asarray(b)))
    # fp32-class request at the same key: strassen is exact-class, so the
    # pin refuses the wholesale swap (and strassen is not tile-transferable)
    with use_table(_strassen_table()):
        plan = select_plan((64, 64, 64), 12, backend="pallas", exact=False)
        assert plan.variant not in STRASSEN_VARIANTS
    # past the composed bound the entry is discarded outright
    with use_table(_strassen_table()):
        plan = select_plan((64, 128, 64), 12, backend="pallas", exact=True)
        assert plan.variant not in STRASSEN_VARIANTS


def test_quantized_matmul_bit_identical_with_strassen_table():
    """The quant path: a strassen entry in the MM1-window exact class is
    adopted through the staged-redirect seam and the fp32 w=12 class
    refuses it — outputs bit-identical with and without the table."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((4, 16, 32)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    for w_bits in (8, 12):
        base = np.asarray(quantized_matmul(x, wm, w_bits))
        with use_table(_strassen_table()):
            tuned = np.asarray(quantized_matmul(x, wm, w_bits))
        np.testing.assert_array_equal(base, tuned, err_msg=f"w={w_bits}")


# ---------------------------------------------------------------------------
# Shard-local bound re-check.
# ---------------------------------------------------------------------------


def test_plan_local_bounds_recheck_strassen():
    from repro.dist.shard_gemm import plan_local_bounds_ok

    plan = _plan("strassen+kmm2", 12)
    ok, _ = plan_local_bounds_ok(plan, (32, 64, 32), 12, 8)
    assert ok
    ok, reason = plan_local_bounds_ok(plan, (32, 128, 32), 12, 8)
    assert not ok and "strassen bounds on local shape" in reason


# ---------------------------------------------------------------------------
# Analytic traffic model ordering (obs/traffic.py satellite).
# ---------------------------------------------------------------------------


def test_analytic_bytes_strassen_ordering():
    from repro.obs.traffic import (STRASSEN_SHAPES, STRASSEN_W,
                                   analytic_bytes)

    for (shape, bk) in STRASSEN_SHAPES:
        tiles = (min(128, shape[0]), min(128, shape[2]), bk)
        fused_sub = analytic_bytes("strassen_kmm2", shape, w=STRASSEN_W,
                                   tiles=tiles)
        xla_sub = analytic_bytes("strassen_xla", shape, w=STRASSEN_W,
                                 tiles=tiles)
        assert 0 < fused_sub < xla_sub, (shape, fused_sub, xla_sub)
