"""Continuous-batching serve engine tests.

Covers the PR-2 acceptance bar: token-identity between continuous batching
and sequential single-request generation on ragged prompts (mixed lengths,
mixed budgets, EOS mid-stream, mixed temperature), the ragged-prefill
regression (padded-group prefill == per-request unpadded prefill), slot
scheduling (1-token request does 1 token of work, slot reuse), and the
throughput accounting fix (tokens/s counts generated tokens, not steps).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Engine, Request, ServeStats


def _tiny_cfg():
    return get_config("llama3.2-1b", smoke=True).scaled_down(
        d_model=64, d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
        head_dim=16)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params = lm.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _mk_requests(cfg, spec):
    rng = np.random.default_rng(0)
    return [Request(prompt=list(rng.integers(1, cfg.vocab_size, size=n)),
                    max_new_tokens=m, temperature=t, stop_tokens=stop)
            for n, m, t, stop in spec]


# ---------------------------------------------------------------------------
# Golden test: continuous batching == sequential generation, token for token.
# ---------------------------------------------------------------------------


def test_continuous_matches_sequential_ragged(tiny):
    cfg, params = tiny
    spec = [(3, 6, 0.0, ()), (9, 1, 0.0, ()), (5, 8, 0.7, ()),
            (12, 4, 0.0, ()), (2, 5, 0.9, ())]

    def run(bs, spec):
        eng = Engine(cfg, params, max_seq=48, batch_size=bs, rng_seed=3)
        reqs = _mk_requests(cfg, spec)
        eng.generate(reqs)
        return [r.generated for r in reqs]

    batched = run(3, spec)
    sequential = run(1, spec)
    assert batched == sequential
    assert [len(g) for g in batched] == [6, 1, 8, 4, 5]

    # EOS mid-stream: pick a token the longest request actually emits
    # mid-generation and rerun both ways with it as a stop token.
    eos = batched[2][2]
    spec_eos = [(n, m, t, (eos,)) for n, m, t, _ in spec]
    b2 = run(3, spec_eos)
    s2 = run(1, spec_eos)
    assert b2 == s2
    assert b2[2][-1] == eos and len(b2[2]) == 3      # truncated at the EOS
    for g, (_, m, _, _) in zip(b2, spec_eos):
        assert len(g) <= m


def test_temperature_rows_deterministic_and_mixed(tiny):
    cfg, params = tiny
    spec = [(4, 5, 0.0, ()), (4, 5, 1.0, ())]

    def run():
        eng = Engine(cfg, params, max_seq=32, batch_size=2, rng_seed=11)
        reqs = _mk_requests(cfg, spec)
        eng.generate(reqs)
        return [r.generated for r in reqs]

    a, b = run(), run()
    assert a == b                       # per-(request, step) keys: replayable
    assert all(len(g) == 5 for g in a)


# ---------------------------------------------------------------------------
# Ragged-prefill regression: padded group == per-request unpadded prefill.
# ---------------------------------------------------------------------------


def _ragged_prefill_check(arch, pad):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(1)
    lens = [3, 9, 5, 12]
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    S, smax, b = 16, 32, len(lens)
    toks = np.zeros((b, S), np.int32)
    pos = np.zeros((b, S), np.int32)
    mask = np.zeros((b, S), bool)
    last = np.zeros((b,), np.int32)
    for i, p in enumerate(prompts):
        n = len(p)
        if pad == "right":
            toks[i, :n] = p
            pos[i] = np.arange(S)
            mask[i, :n] = True
            last[i] = n - 1
        else:
            toks[i, S - n:] = p
            pos[i, S - n:] = np.arange(n)
            mask[i, S - n:] = True
            last[i] = S - 1
    cache = lm.init_cache(cfg, b, smax)
    logits, _, _ = lm.prefill(
        params, cfg, jnp.asarray(toks), cache, positions=jnp.asarray(pos),
        pad_mask=jnp.asarray(mask), last_idx=jnp.asarray(last))
    for i, p in enumerate(prompts):
        c1 = lm.init_cache(cfg, 1, smax)
        ref, _, _ = lm.prefill(params, cfg, jnp.asarray(p[None]), c1)
        np.testing.assert_allclose(
            np.asarray(logits[i], np.float32),
            np.asarray(ref[0], np.float32), atol=1e-4, rtol=1e-4,
            err_msg=f"{arch} {pad}-pad row {i} (len {len(p)})")


@pytest.mark.parametrize("pad", ["right", "left"])
def test_ragged_prefill_matches_unpadded_attn(pad):
    _ragged_prefill_check("llama3.2-1b", pad)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "rwkv6-3b"])
@pytest.mark.parametrize("pad", ["right", "left"])
def test_ragged_prefill_matches_unpadded_recurrent(arch, pad):
    """Recurrent state (mamba conv/ssm, rwkv shift/wkv) across pad tokens."""
    _ragged_prefill_check(arch, pad)


# ---------------------------------------------------------------------------
# Scheduler behaviour.
# ---------------------------------------------------------------------------


def test_one_token_request_does_one_token_of_work(tiny):
    """A 1-token request in a group with a long request must not ride the
    long request's decode loop (the old group barrier ran max(max_new) steps
    for everyone and appended past the budget)."""
    cfg, params = tiny
    eng = Engine(cfg, params, max_seq=48, batch_size=2)
    reqs = _mk_requests(cfg, [(4, 1, 0.0, ()), (4, 12, 0.0, ())])
    stats = eng.generate(reqs)
    assert [len(r.generated) for r in reqs] == [1, 12]
    # first tokens come from prefill; the batch only steps for the long one
    assert stats.decode_steps == 11
    assert stats.generated_tokens == 13

    # alone, a 1-token request takes zero decode steps
    eng1 = Engine(cfg, params, max_seq=48, batch_size=1)
    r = _mk_requests(cfg, [(4, 1, 0.0, ())])
    s1 = eng1.generate(r)
    assert len(r[0].generated) == 1 and s1.decode_steps == 0

    # streaming API: a request finishing at admission is still reported by
    # the step() that admitted it
    r2 = _mk_requests(cfg, [(4, 1, 0.0, ())])[0]
    eng1.submit(r2)
    assert eng1.step() == [r2] and len(r2.generated) == 1


def test_tokens_per_s_counts_generated_tokens(tiny):
    cfg, params = tiny
    # pure accounting: 10 tokens in 2s of model time = 5 tok/s, whatever
    # the number of batch steps
    s = ServeStats(decode_s=2.0, decode_steps=64, generated_tokens=10)
    assert s.tokens_per_s == 5.0
    eng = Engine(cfg, params, max_seq=48, batch_size=4)
    reqs = _mk_requests(cfg, [(3, 4, 0.0, ())] * 4)
    stats = eng.generate(reqs)
    assert stats.generated_tokens == sum(len(r.generated) for r in reqs) == 16
    # measured runs divide by the engine-busy wall-clock span, which covers
    # at least the model time (prefill + decode) plus host bookkeeping
    assert stats.busy_s >= stats.prefill_s + stats.decode_s
    assert stats.tokens_per_s == pytest.approx(
        stats.generated_tokens / stats.busy_s)
    # a 1-token workload produces all its tokens in prefill: decode_s is 0
    # but throughput must still be real (the old metric divided by zero)
    r1 = _mk_requests(cfg, [(3, 1, 0.0, ())] * 2)
    s1 = eng.generate(r1)
    assert s1.decode_steps == 0 and s1.generated_tokens == 2
    assert s1.tokens_per_s > 0


def test_slot_reuse_and_continuous_admission(tiny):
    """More requests than slots: freed slots are refilled mid-flight and
    every request completes; the decode jit never retraces."""
    cfg, params = tiny
    eng = Engine(cfg, params, max_seq=48, batch_size=2)
    spec = [(3, 2, 0.0, ()), (5, 6, 0.0, ()), (4, 3, 0.0, ()),
            (6, 1, 0.0, ()), (2, 4, 0.0, ())]
    reqs = _mk_requests(cfg, spec)
    stats = eng.generate(reqs)
    assert [len(r.generated) for r in reqs] == [m for _, m, _, _ in spec]
    assert len(stats.requests) == 5
    # bucketed decode on a 2-slot engine traces at most widths {1, 2}
    nt = eng.n_traces()["decode"]
    assert nt == -1 or 1 <= nt <= 2
    # continuous batching: total steps is far below the group-barrier cost
    # (ceil(5/2) groups x max_new=6 would be 18 steps)
    assert stats.decode_steps < 18


def test_eos_and_stats(tiny):
    cfg, params = tiny
    eng = Engine(cfg, params, max_seq=48, batch_size=2)
    reqs = _mk_requests(cfg, [(4, 8, 0.0, ()), (5, 8, 0.0, ())])
    eng.generate(reqs)
    eos = reqs[0].generated[1]
    reqs2 = _mk_requests(cfg, [(4, 8, 0.0, (eos,)), (5, 8, 0.0, ())])
    stats = eng.generate(reqs2)
    cut = reqs[0].generated.index(eos) + 1    # truncated at first occurrence
    assert reqs2[0].generated == reqs[0].generated[:cut]
    by_rid = {r.rid: r for r in stats.requests}
    gen_by_rid = {r.stats.rid: r.generated for r in reqs2}
    assert by_rid[reqs2[0].stats.rid].stop_reason == "stop_token"
    assert by_rid[reqs2[1].stats.rid].stop_reason == "length"
    for rs in stats.requests:
        assert rs.prompt_len in (4, 5)
        assert rs.first_token_s >= rs.arrival_s
        assert rs.latency_s >= rs.ttft_s >= 0
        assert rs.n_tokens == len(gen_by_rid[rs.rid])


def test_granite_moe_grouped_serve_smoke():
    """PR-9 acceptance: a quantized MoE model serves with its expert GEMMs
    routed through the ragged grouped fused kernel (live counts from the
    capacity dispatch), token-identical to the XLA backend — and the route
    + dispatch metrics prove the path was actually taken."""
    import dataclasses
    from repro.obs import metrics as obs_metrics

    cfg = get_config("granite-moe-3b-a800m", smoke=True)
    cfg = cfg.with_quant(dataclasses.replace(
        cfg.quant, enabled=True, default_bits=8))
    params = lm.init_params(jax.random.PRNGKey(5), cfg)
    spec = [(6, 4, 0.0, ()), (3, 3, 0.0, ())]

    def run(backend):
        eng = Engine(cfg, params, max_seq=32, batch_size=2,
                     quant_backend=backend, rng_seed=5)
        reqs = _mk_requests(cfg, spec)
        eng.generate(reqs)
        return [r.generated for r in reqs]

    obs_metrics.enable()
    try:
        obs_metrics.reset()
        xla_toks = run("xla")
        pal_toks = run("pallas")
        routes = obs_metrics.get("repro_quant_gemm_routes_total")
        assert routes.value("pallas", "pallas") > 0, \
            "no quantized GEMM actually took the pallas route"
        hist = obs_metrics.snapshot().get("repro_moe_tokens_per_expert")
        assert hist and any(v["count"] > 0 for v in hist["values"].values()), \
            "MoE dispatch histogram never observed"
    finally:
        obs_metrics.disable()
        obs_metrics.reset()
    assert xla_toks == pal_toks, "pallas MoE serve is not token-identical"


def test_submit_rejects_oversized(tiny):
    cfg, params = tiny
    eng = Engine(cfg, params, max_seq=16, batch_size=1)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=[1] * 10, max_new_tokens=10))
    # the first token is produced at admission, so a zero budget is
    # rejected up front rather than silently over-generating
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=[1, 2], max_new_tokens=0))
    # custom buckets bound the admissible prompt length at submit time
    # (not deep inside the serve loop, where the request would be lost)
    eng2 = Engine(cfg, params, max_seq=64, batch_size=1, prompt_buckets=[8])
    with pytest.raises(ValueError):
        eng2.submit(Request(prompt=[1] * 20, max_new_tokens=8))


def test_encdec_unsupported_is_explicit():
    cfg = get_config("seamless-m4t-medium", smoke=True)
    assert cfg.is_encdec
    with pytest.raises(NotImplementedError):
        Engine(cfg, params={}, max_seq=16, batch_size=1)


def test_arrival_trace_queues_admission(tiny):
    cfg, params = tiny
    eng = Engine(cfg, params, max_seq=32, batch_size=2)
    reqs = _mk_requests(cfg, [(3, 2, 0.0, ()), (3, 2, 0.0, ()),
                              (3, 2, 0.0, ())])
    stats = eng.generate(reqs, arrival_s=[0.0, 0.05, 0.1])
    assert all(len(r.generated) == 2 for r in reqs)
    for rs in stats.requests:
        assert rs.first_token_s >= rs.arrival_s


def test_tuning_table_changes_no_tokens(tmp_path):
    """Serving with a repro.tune table installed (Engine(tuning_table=...))
    is token-identical to serving without one: the registry only retunes
    how quantized GEMMs execute, never what they compute."""
    from repro.core.dispatch import ExecPlan
    from repro.tune import TuningTable, get_active_table, set_active_table

    qcfg = get_config("llama3.2-1b", smoke=True, quant="w8").scaled_down(
        d_model=64, d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
        head_dim=16)
    qparams = lm.init_params(jax.random.PRNGKey(7), qcfg)
    spec = [(3, 3, 0.0, ()), (6, 2, 0.8, ())]

    def run(table_path):
        eng = Engine(qcfg, qparams, max_seq=32, batch_size=2, rng_seed=1,
                     tuning_table=table_path)
        reqs = _mk_requests(qcfg, spec)
        eng.generate(reqs)
        return [r.generated for r in reqs]

    try:
        base = run(None)
        # one entry targeting the decode GEMM bucket + prior fallback for
        # every other key (both paths must preserve numerics)
        t = TuningTable(device="test")
        t.put("xla", (2, 64, 64), 8,
              ExecPlan("mm2", 8, backend="xla", depth=1,
                       combine_int32=False))
        path = tmp_path / "serve_table.json"
        t.save(path)
        tuned = run(str(path))
        assert get_active_table() is not None
        assert base == tuned
    finally:
        set_active_table(None)


# ---------------------------------------------------------------------------
# PR-5 acceptance: backend="pallas" serves through the fused kernel with
# token-identical output vs the XLA backend (default, non-tabled dispatch).
# ---------------------------------------------------------------------------


def test_serve_quant_backend_pallas_token_identical(tiny):
    cfg, params = tiny
    spec = [(5, 4, 0.0, ()), (9, 3, 0.8, ()), (3, 4, 0.0, ())]

    def run(backend, quant):
        qcfg = cfg.with_quant(get_config("llama3.2-1b", smoke=True,
                                         quant=quant).quant)
        eng = Engine(qcfg, params, max_seq=32, batch_size=2, rng_seed=5,
                     quant_backend=backend)
        reqs = _mk_requests(qcfg, spec)
        eng.generate(reqs)
        return [r.generated for r in reqs]

    # w8: the exact-int class makes the fused epilogue bit-identical to the
    # XLA dequant, so logits — and therefore tokens, greedy AND sampled —
    # cannot differ.
    assert run("xla", "w8") == run("pallas", "w8")
    # w12: the fused kernel is in the staged-pallas fp32 class; at serve
    # scale the accumulators stay integer-exact in fp32, so tokens match
    # the XLA digit recursion too.
    assert run("xla", "w12") == run("pallas", "w12")


def test_engine_pallas_under_mesh_negotiates(tiny):
    """The old hard mesh-rejection is gone: pallas + mesh serves through
    capability negotiation.  On a 1x1 mesh no axis can tile any GEMM, so
    every quantized matmul downgrades to XLA (logged) — and the tokens must
    match the same engine without a mesh."""
    from repro.core.context import ExecContext

    cfg, params = tiny
    qcfg = cfg.with_quant(get_config("llama3.2-1b", smoke=True,
                                     quant="w8").quant)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    spec = [(5, 3, 0.0, ()), (9, 2, 0.8, ())]

    def run(mesh_arg):
        eng = Engine(qcfg, params, max_seq=32, batch_size=2, rng_seed=5,
                     context=ExecContext(backend="pallas", mesh=mesh_arg))
        assert eng.context.backend == "pallas"
        reqs = _mk_requests(qcfg, spec)
        eng.generate(reqs)
        return [r.generated for r in reqs]

    assert run(mesh) == run(None)
