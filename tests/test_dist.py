"""Fast (1-device) unit tests for repro.dist: compression round-trips,
ring matmul and split-K attention on trivial meshes, and the sharding rule
table against a fake 2x4 mesh (the real multi-device path is covered by
tests/test_sharded.py)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.dist import sharding as shard
from repro.dist.collectives import (
    ef_compress, ring_ag_matmul, splitk_decode_attention)
from repro.launch.mesh import single_device_mesh


# ---------------------------------------------------------------------------
# ef_compress.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [4, 8])
def test_ef_compress_roundtrip_bounds(bits):
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((128,)), jnp.float32)
    err = jnp.zeros((128,))
    for _ in range(3):
        q, scale, err = ef_compress(x, err, bits=bits)
        # residual is at most half a quantization step
        assert float(jnp.abs(err).max()) <= float(scale) * 0.5 + 1e-7
        # lossless round-trip: q * scale + err reconstructs the input
        recon = q.astype(jnp.float32) * scale + err
        np.testing.assert_allclose(np.asarray(recon), np.asarray(x),
                                   atol=float(scale) + 1e-6)
    qmax = 2 ** (bits - 1) - 1
    assert int(jnp.abs(q.astype(jnp.int32)).max()) <= qmax


def test_ef_compress_error_decreases_with_bits():
    rng = np.random.default_rng(1)
    x = jnp.array(rng.standard_normal((256,)), jnp.float32)
    errs = []
    for bits in (4, 6, 8):
        _, _, err = ef_compress(x, jnp.zeros_like(x), bits=bits)
        errs.append(float(jnp.abs(err).max()))
    assert errs[0] > errs[1] > errs[2]


def test_ef_compress_zero_input_safe():
    x = jnp.zeros((16,))
    q, scale, err = ef_compress(x, jnp.zeros_like(x))
    assert float(jnp.abs(err).max()) == 0.0
    assert int(jnp.abs(q.astype(jnp.int32)).max()) == 0


# ---------------------------------------------------------------------------
# Collectives on a 1-device mesh (axis size 1: pure local math).
# ---------------------------------------------------------------------------


def test_ring_ag_matmul_matches_dense():
    mesh = single_device_mesh()
    rng = np.random.default_rng(2)
    x = jnp.array(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.array(rng.standard_normal((16, 4)), jnp.float32)
    f = shard_map(lambda xs, w: ring_ag_matmul(xs, w, "model"),
                  mesh=mesh, in_specs=(P("model", None), P(None, None)),
                  out_specs=P(None, None), check_rep=False)
    np.testing.assert_allclose(np.asarray(f(x, w)), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


def test_ring_ag_matmul_int_path():
    mesh = single_device_mesh()
    rng = np.random.default_rng(3)
    x = jnp.array(rng.standard_normal((4, 32)), jnp.float32)
    w = jnp.array(rng.standard_normal((32, 8)), jnp.float32)
    f = shard_map(lambda xs, w: ring_ag_matmul(xs, w, "model", w_bits=8),
                  mesh=mesh, in_specs=(P("model", None), P(None, None)),
                  out_specs=P(None, None), check_rep=False)
    ref = np.asarray(x @ w)
    got = np.asarray(f(x, w))
    # int8-quantized operands: first-order quantization noise
    assert np.abs(got - ref).max() < 0.05 * np.abs(ref).max() + 0.05


def test_splitk_decode_attention_matches_softmax():
    mesh = single_device_mesh()
    rng = np.random.default_rng(4)
    B, S, H, D = 2, 16, 4, 8
    q = jnp.array(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.array(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.array(rng.standard_normal((B, S, H, D)), jnp.float32)
    valid = jnp.arange(S)[None, :] < jnp.array([[S], [S // 2]])[:, 0, None]
    f = shard_map(lambda q, k, v, m: splitk_decode_attention(q, k, v, m,
                                                             "model"),
                  mesh=mesh,
                  in_specs=(P(), P(None, "model"), P(None, "model"),
                            P(None, "model")),
                  out_specs=P(), check_rep=False)
    out = f(q, k, v, valid)
    scores = jnp.einsum("bhd,bshd->bhs", q, k) * (D ** -0.5)
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    ref = jnp.einsum("bhs,bshd->bhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Sharding rules (spec logic is mesh-shape driven; fake a 2x4 mesh).
# ---------------------------------------------------------------------------


class _FakeMesh:
    """Duck-typed stand-in with the attrs leaf_spec/batch_spec consume."""

    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


def _spec(tree, mesh):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    (path, leaf), = flat
    return tuple(shard.leaf_spec(path, leaf, mesh))


def test_param_spec_shapes_2x4():
    mesh = _FakeMesh({"data": 2, "model": 4})
    sds = jax.ShapeDtypeStruct
    # stacked FFN up-proj: (periods, d, ff) -> ff on model, d on data
    assert _spec({"blocks": {"pos0": {"mlp": {
        "wi": sds((3, 256, 1024), jnp.float32)}}}}, mesh) == \
        (None, "data", "model")
    # output proj: row TP, column FSDP
    assert _spec({"blocks": {"pos0": {"attn": {
        "wo": sds((3, 256, 256), jnp.float32)}}}}, mesh) == \
        (None, "model", "data")
    # embedding: vocab-parallel
    assert _spec({"embed": sds((2048, 256), jnp.float32)}, mesh) == \
        ("model", "data")
    # MoE experts ride the model axis; d stays FSDP
    assert _spec({"blocks": {"pos0": {"moe": {
        "wi": sds((3, 4, 256, 512), jnp.float32)}}}}, mesh)[1] == "model"
    # norms replicated
    assert _spec({"ln_f": {"scale": sds((256,), jnp.float32)}}, mesh) == ()


def test_param_spec_divisibility_guard():
    mesh = _FakeMesh({"data": 2, "model": 4})
    sds = jax.ShapeDtypeStruct
    # 255 is not divisible by 2, 1022 not by 4: both dims drop their axis
    assert _spec({"mlp": {"wi": sds((255, 1022), jnp.float32)}}, mesh) == \
        (None, None)


def test_batch_spec_axes():
    assert tuple(shard.batch_spec(_FakeMesh({"data": 2, "model": 4}))) == \
        ("data",)
    assert tuple(shard.batch_spec(
        _FakeMesh({"pod": 2, "data": 4, "model": 2}))) == (("pod", "data"),)
    assert len(shard.batch_spec(_FakeMesh({"model": 8}))) == 0


def test_param_sharding_tree_matches_params():
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = single_device_mesh()
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    sh = shard.param_sharding(shapes, mesh)
    assert jax.tree_util.tree_structure(sh) == \
        jax.tree_util.tree_structure(shapes)
    for s, l in zip(jax.tree_util.tree_leaves(sh),
                    jax.tree_util.tree_leaves(shapes)):
        assert len(s.spec) <= len(l.shape)


def test_cache_sharding_tree():
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = single_device_mesh()
    shapes = jax.eval_shape(lambda: lm.init_cache(cfg, 4, 32))
    cs = shard.cache_sharding(shapes, mesh, batch=4)
    assert jax.tree_util.tree_structure(cs) == \
        jax.tree_util.tree_structure(shapes)


def test_constrain_batch_dim_noop_outside_mesh():
    x = jnp.ones((4, 8))
    assert shard.constrain_batch_dim(x) is x


# ---------------------------------------------------------------------------
# Serve engine wiring (mesh-aware path on one device).
# ---------------------------------------------------------------------------


def test_engine_mesh_matches_unsharded():
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import Engine, Request

    cfg = get_config("llama3.2-1b", smoke=True).scaled_down(
        d_model=64, d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
        head_dim=16)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    def run(mesh):
        reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4)]
        Engine(cfg, params, max_seq=32, mesh=mesh).generate(reqs)
        return reqs[0].generated

    assert run(None) == run(single_device_mesh())
