"""Complexity / area / efficiency model tests — the paper's analytical claims
(Eqs. 2-23, Figs. 5, 11, 12) must reproduce from our implementation."""
import math

import pytest

from repro.core.complexity import (
    kmm_arith, kmm_complexity, ksm_complexity, ksmm_arith, ksmm_complexity,
    mm_arith, mm_complexity,
)
from repro.core.area import (
    area_kmm, area_ksmm, area_mm1, au_efficiency_vs_mm1, best_kmm_levels,
)
from repro.core.efficiency import Measured, precision_scalable_roof, roof

D = 64


class TestClosedForms:
    """Recursive op counts equal closed forms (exact at n=2)."""

    @pytest.mark.parametrize("w", [16, 32])
    def test_n2_exact(self, w):
        assert mm_complexity(2, w, D).total() == mm_arith(2, D)
        assert kmm_complexity(2, w, D).total() == kmm_arith(2, D)
        assert ksmm_complexity(2, w, D).total() == ksmm_arith(2, D)

    @pytest.mark.parametrize("n", [4, 8])
    def test_leading_order(self, n):
        # closed forms are leading-order for n > 2: within 5% at d=64
        assert mm_complexity(n, 32, D).total() == pytest.approx(
            mm_arith(n, D), rel=0.05)
        assert kmm_complexity(n, 32, D).total() == pytest.approx(
            kmm_arith(n, D), rel=0.10)


class TestFig5Claims:
    def test_ksmm_needs_75pct_more_than_kmm(self):
        for n in (2, 4, 8, 16, 32):
            assert ksmm_arith(n, D) / kmm_arith(n, D) > 1.75

    def test_kmm_beats_mm_from_n2(self):
        assert kmm_arith(2, D) < mm_arith(2, D)

    def test_ksmm_beats_mm_only_beyond_n4(self):
        assert ksmm_arith(2, D) > mm_arith(2, D)
        assert ksmm_arith(4, D) > mm_arith(4, D)
        assert ksmm_arith(8, D) < mm_arith(8, D)


class TestAlg5Accounting:
    def test_wide_adds_reduced_by_p(self):
        """Eq. 10: with pre-accumulation p, wide (2w+wa)-bit adds drop by p."""
        flat = mm_complexity(1, 8, D, p=None)
        pre = mm_complexity(1, 8, D, p=4)
        wa = math.ceil(math.log2(D))
        wide_flat = flat.counts[("ACCUM", 16 + wa)]
        wide_pre = pre.counts[("ADD", 16 + wa)]
        assert wide_pre == wide_flat / 4
        # total op count is unchanged: (p-1) narrow + 1 wide per p products
        assert pre.total() == flat.total()


class TestAreaModel:
    def test_kmm_smaller_than_mm1_from_24bit(self):
        # Fig. 12: KMM passes MM1 earlier (lower w) than KSMM
        assert area_kmm(2, 24) < area_mm1(24)
        assert area_ksmm(2, 24) > area_mm1(24)
        assert area_ksmm(2, 32) < area_mm1(32)

    def test_kmm_always_beats_ksmm(self):
        for w in (8, 16, 24, 32, 40, 48, 56, 64):
            assert area_kmm(2, w) < area_ksmm(2, w)

    def test_recursion_level_rule(self):
        # paper: 1 level for 8-32, 2 for 40-56 (our model picks 2 at 64 by a
        # 1.3% margin where the paper reports 3 — documented deviation)
        for w in (8, 16, 24, 32):
            assert best_kmm_levels(w) == 1
        for w in (40, 48, 56):
            assert best_kmm_levels(w) == 2
        assert best_kmm_levels(64) in (2, 3)

    def test_au_efficiency_ordering(self):
        for w in (24, 32, 48, 64):
            kmm = au_efficiency_vs_mm1("kmm", w).relative
            ksmm = au_efficiency_vs_mm1("ksmm", w, n=2).relative
            assert kmm > ksmm


class TestEfficiencyMetric:
    def test_roofs(self):
        assert roof("mm", 16, 8) == 1.0
        assert roof("kmm", 16, 8) == pytest.approx(4 / 3)
        assert roof("kmm", 32, 8) == pytest.approx((4 / 3) ** 2)
        assert roof("ffip", 16, 8) == 2.0
        assert roof("ffip_kmm", 16, 8) == pytest.approx(8 / 3)

    def test_precision_scalable_fig11(self):
        assert precision_scalable_roof("mm", 8, 8) == 1.0
        assert precision_scalable_roof("mm", 12, 8) == 1.0
        assert precision_scalable_roof("kmm", 12, 8) == pytest.approx(4 / 3)
        assert precision_scalable_roof("kmm", 16, 8) == 1.0
        assert precision_scalable_roof("ffip_kmm", 12, 8) == pytest.approx(8 / 3)

    def test_measured_metric_matches_roof_at_full_utilization(self):
        """A KMM2 64x64 MXU running N products in 3 passes/tile hits 4/3."""
        x = y = 64
        n_tiles = 1000
        cycles = n_tiles * 3 * 64          # 3 passes, 64 rows each
        m = Measured(n_w_products=n_tiles * 64 * 64 * 64, w=12, m=8,
                     cycles=cycles, n_multipliers=x * y)
        assert m.efficiency == pytest.approx(4 / 3)
