"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs,
plus a serve-path prefill/decode consistency check."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_applicable, get_config, list_archs
from repro.models import lm

B, S = 2, 32

# The deep/hybrid smoke configs dominate tier-1 wall time (jamba's 8-block
# pattern alone is ~1.5 min across the three tests); they run in the CI
# slow job instead.
SLOW_ARCHS = {"jamba-v0.1-52b", "seamless-m4t-medium"}
# the (quantized) train-grad step is the most expensive per-arch case;
# tier-1 keeps one arch per family and the slow job covers the rest
SLOW_GRAD_ARCHS = SLOW_ARCHS | {"gemma-2b", "granite-moe-3b-a800m",
                                "llava-next-mistral-7b",
                                "qwen3-moe-30b-a3b", "stablelm-12b"}


def _archs(slow_set=SLOW_ARCHS):
    return [pytest.param(a, marks=pytest.mark.slow) if a in slow_set else a
            for a in list_archs()]


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim))
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(key, (B, S, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", _archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = lm.forward_train(
        params, cfg, batch["tokens"],
        frontend_embeds=batch.get("frontend_embeds"),
        enc_frames=batch.get("enc_frames"))
    s_out = S + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, s_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", _archs(SLOW_GRAD_ARCHS))
def test_train_step_finite_grads(arch):
    cfg = get_config(arch, smoke=True, quant="mixed")
    key = jax.random.PRNGKey(1)
    params = lm.init_params(key, cfg)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.isfinite(np.asarray(g, np.float32)).all(), path


@pytest.mark.parametrize("arch", _archs())
def test_prefill_decode_consistency(arch):
    """decode_step at position S must match forward_train's next-token logits
    (KV cache/recurrent state correctness across the prefill/decode split)."""
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(2)
    params = lm.init_params(key, cfg)
    batch = _batch(cfg, key)
    tokens = batch["tokens"]

    cache = lm.init_cache(cfg, B, S + 8)
    last_logits, cache, mem = lm.prefill(
        params, cfg, tokens, cache,
        frontend_embeds=batch.get("frontend_embeds"),
        enc_frames=batch.get("enc_frames"))
    full_logits, _ = lm.forward_train(
        params, cfg, tokens,
        frontend_embeds=batch.get("frontend_embeds"),
        enc_frames=batch.get("enc_frames"))
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(full_logits[:, -1, :], np.float32), atol=2e-2, rtol=2e-2)

    # one decode step continues the sequence
    nt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    pos = S + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    logits2, cache2 = lm.decode_step(params, cfg, nt, cache, jnp.int32(pos),
                                     mem=mem)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # and it must equal the train-forward logits on the extended sequence
    if cfg.frontend != "vision":
        ext = jnp.concatenate([tokens, nt[:, None]], axis=1)
        full2, _ = lm.forward_train(params, cfg, ext,
                                    enc_frames=batch.get("enc_frames"))
        np.testing.assert_allclose(
            np.asarray(logits2, np.float32),
            np.asarray(full2[:, -1, :], np.float32), atol=2e-2, rtol=2e-2)


def test_long_500k_applicability_rules():
    applicable = {a for a in list_archs()
                  if cell_applicable(get_config(a), "long_500k")}
    assert applicable == {"jamba-v0.1-52b", "rwkv6-3b"}
    for a in list_archs():
        assert cell_applicable(get_config(a), "train_4k")


def test_param_counts_full_configs():
    """Full configs land near their nameplate sizes (sanity of the exact
    config transcription; MODEL_FLOPS in the roofline uses these counts)."""
    from repro.models.config import count_active_params, count_params
    expect = {
        "gemma-2b": (2.0e9, 3.5e9),
        "nemotron-4-15b": (14e9, 17e9),
        "stablelm-12b": (11e9, 13.5e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "jamba-v0.1-52b": (48e9, 56e9),
        "rwkv6-3b": (2.5e9, 3.5e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
    active = count_active_params(get_config("qwen3-moe-30b-a3b"))
    assert 2e9 < active < 4.5e9  # ~3B active
