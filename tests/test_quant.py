"""Quantized-matmul layer tests: error bounds, STE gradients, mode routing,
and the batched expert path."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, strategies as st

from repro.quant.policy import POLICY_MIXED, POLICY_W12, QuantConfig
from repro.quant.qmatmul import (
    maybe_quantized_matmul, quantized_matmul, quantized_matmul_batched,
)


def test_error_decreases_with_bits():
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((32, 128)), jnp.float32)
    w = jnp.array(rng.standard_normal((128, 64)), jnp.float32)
    ref = np.asarray(x @ w)
    errs = []
    for bits in (4, 8, 12):
        out = np.asarray(quantized_matmul(x, w, bits))
        errs.append(np.abs(out - ref).max() / np.abs(ref).max())
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 2e-3


@pytest.mark.parametrize("bits", [8, 12, 16])
def test_relative_error_bound(bits):
    rng = np.random.default_rng(bits)
    x = jnp.array(rng.standard_normal((16, 256)), jnp.float32)
    w = jnp.array(rng.standard_normal((256, 32)), jnp.float32)
    ref = np.asarray(x @ w)
    out = np.asarray(quantized_matmul(x, w, bits))
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    # ~ K * q_err^2 accumulation; generous envelope per bit level
    assert rel < {8: 0.05, 12: 0.004, 16: 1e-3}[bits]


def test_ste_gradients_match_full_precision():
    rng = np.random.default_rng(1)
    x = jnp.array(rng.standard_normal((8, 32)), jnp.float32)
    w = jnp.array(rng.standard_normal((32, 16)), jnp.float32)

    gx_q, gw_q = jax.grad(
        lambda x, w: quantized_matmul(x, w, 8).sum(), argnums=(0, 1))(x, w)
    gx_f, gw_f = jax.grad(lambda x, w: (x @ w).sum(), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_q), np.asarray(gx_f), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_q), np.asarray(gw_f), rtol=1e-5)


def test_batched_expert_path():
    rng = np.random.default_rng(2)
    x = jnp.array(rng.standard_normal((4, 10, 32)), jnp.float32)   # (E,C,K)
    w = jnp.array(rng.standard_normal((4, 32, 16)), jnp.float32)   # (E,K,N)
    out = np.asarray(quantized_matmul_batched(x, w, 12))
    ref = np.asarray(jnp.einsum("eck,ekn->ecn", x, w))
    assert np.abs(out - ref).max() / np.abs(ref).max() < 0.004


def test_policy_routing():
    q = POLICY_MIXED
    assert q.bits_for("blk0.mlp.wi") == 8
    assert q.bits_for("lm_head") == 12
    assert q.bits_for("blk3.attn.o_proj") == 12
    assert q.plan_for("lm_head").mode.value == "kmm2"
    assert q.plan_for("blk0.mlp.wi").mode.value == "mm1"
    assert POLICY_W12.plan_for("anything").passes == 3


def test_disabled_quant_is_plain_matmul():
    rng = np.random.default_rng(3)
    x = jnp.array(rng.standard_normal((4, 8)), jnp.bfloat16)
    w = jnp.array(rng.standard_normal((8, 4)), jnp.float32)
    out = maybe_quantized_matmul(x, w, QuantConfig(), "any")
    ref = x @ w.astype(jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=1e-2)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(bits=st.integers(6, 14), m_dim=st.integers(1, 16),
       k_dim=st.integers(8, 64), seed=st.integers(0, 2**31 - 1))
def test_property_quant_error_envelope(bits, m_dim, k_dim, seed):
    """|quantized - exact| bounded by first-order quantization noise."""
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.standard_normal((m_dim, k_dim)), jnp.float32)
    w = jnp.array(rng.standard_normal((k_dim, 8)), jnp.float32)
    out = np.asarray(quantized_matmul(x, w, bits))
    ref = np.asarray(x @ w)
    qstep = 2.0 ** (1 - bits)
    # per-element: sum_k (|x| dW + |w| dX + dXdW); envelope with margin
    bound = 4.0 * qstep * np.abs(np.asarray(x)).max() \
        * np.abs(np.asarray(w)).max() * k_dim + 1e-5
    assert np.abs(out - ref).max() < bound
