"""Substrate tests: optimizer vs numpy reference, data pipeline determinism,
checkpoint atomicity/roundtrip, MoE dispatch invariants, recurrent-block
consistency."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, DataIterator
from repro.train import checkpoint as ckpt
from repro.train import optim


# ---------------------------------------------------------------------------
# Optimizer.
# ---------------------------------------------------------------------------


def _adamw_numpy(p, g, mu, nu, step, cfg):
    mu = cfg.beta1 * mu + (1 - cfg.beta1) * g
    nu = cfg.beta2 * nu + (1 - cfg.beta2) * g * g
    c1 = 1 - cfg.beta1**step
    c2 = 1 - cfg.beta2**step
    upd = (mu / c1) / (np.sqrt(nu / c2) + cfg.eps)
    if p.ndim >= 2:
        upd = upd + cfg.weight_decay * p
    return p - cfg.lr * upd, mu, nu


def test_adamw_matches_numpy_reference():
    cfg = optim.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10**9,
                            grad_clip=1e9, min_lr_ratio=1.0)
    rng = np.random.default_rng(0)
    p = {"w": jnp.array(rng.standard_normal((4, 4)), jnp.float32),
         "b": jnp.array(rng.standard_normal((4,)), jnp.float32)}
    state = optim.init(p)
    p_np = {k: np.asarray(v) for k, v in p.items()}
    mu = {k: np.zeros_like(v) for k, v in p_np.items()}
    nu = {k: np.zeros_like(v) for k, v in p_np.items()}
    for step in range(1, 4):
        g = {k: np.asarray(
            rng.standard_normal(v.shape), np.float32) for k, v in p_np.items()}
        p, state, _ = optim.update(cfg, jax.tree.map(jnp.asarray, g), state, p)
        for k in p_np:
            p_np[k], mu[k], nu[k] = _adamw_numpy(p_np[k], g[k], mu[k], nu[k],
                                                 step, cfg)
        for k in p_np:
            np.testing.assert_allclose(np.asarray(p[k]), p_np[k], rtol=2e-5,
                                       atol=1e-6)


def test_grad_clip():
    cfg = optim.AdamWConfig(grad_clip=1.0)
    g = {"w": jnp.full((100,), 10.0)}
    assert float(optim.global_norm(g)) == pytest.approx(100.0)
    p = {"w": jnp.zeros((100,))}
    state = optim.init(p)
    _, _, metrics = optim.update(cfg, g, state, p)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


def test_lr_schedule():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_ratio=0.1)
    assert float(optim.lr_at(cfg, jnp.int32(0))) == 0.0
    assert float(optim.lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(optim.lr_at(cfg, jnp.int32(110))) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# Data pipeline.
# ---------------------------------------------------------------------------


def test_data_deterministic_and_skippable():
    cfg = DataConfig(seed=7, vocab_size=101, seq_len=32, global_batch=4)
    it1 = DataIterator(cfg)
    batches = [next(it1) for _ in range(5)]
    it2 = DataIterator(cfg)
    it2.skip_to(3)
    b3 = next(it2)
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    assert batches[0]["tokens"].shape == (4, 32)
    assert (batches[0]["labels"][:, :-1] == batches[0]["tokens"][:, 1:]).all()
    # different steps differ
    assert not (batches[0]["tokens"] == batches[1]["tokens"]).all()


def test_data_has_learnable_structure():
    cfg = DataConfig(seed=0, vocab_size=1000, seq_len=256, global_batch=8)
    b = DataIterator(cfg).peek()
    # motif structure => strongly repeated bigrams vs uniform
    toks = b["tokens"]
    uniq = len(set(map(tuple, toks.reshape(-1, 16))))
    assert uniq < toks.size / 16 * 0.9


# ---------------------------------------------------------------------------
# Checkpointing.
# ---------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.array(rng.standard_normal((4, 8)), jnp.float32),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    ckpt.save(d, 5, t, meta={"arch": "x"})
    step, restored, manifest = ckpt.load(d, jax.tree.map(jnp.zeros_like, t))
    assert step == 5 and manifest["arch"] == "x"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_checkpoint_latest_and_prune(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, t)
    assert ckpt.latest_step(d) == 5
    ckpt.prune(d, keep=2)
    remaining = sorted(os.listdir(d))
    assert remaining == ["step_00000004", "step_00000005"]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros((6,), jnp.int32)}}
    with pytest.raises(ValueError):
        ckpt.load(d, bad)


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _tree())
    assert not [n for n in os.listdir(d) if n.startswith(".tmp")]


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    saver = ckpt.AsyncCheckpointer(d, keep=2)
    t = _tree()
    saver.save(1, t)
    saver.save(2, t)   # waits for save 1
    saver.wait()
    assert ckpt.latest_step(d) == 2


# ---------------------------------------------------------------------------
# MoE dispatch invariants.
# ---------------------------------------------------------------------------


def test_moe_matches_dense_reference():
    """With generous capacity, sort-based dispatch must equal the dense
    per-token expert mixture."""
    from repro.configs import get_config
    from repro.models import moe as M

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    cfg = cfg.scaled_down(capacity_factor=8.0)   # no drops
    key = jax.random.PRNGKey(0)
    p = M.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = M.moe_apply(p, x, cfg, None, "t")

    # dense reference
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    up = jnp.einsum("bsd,edf->bsef", x, p["wi"])
    gate = jnp.einsum("bsd,edf->bsef", x, p["wg"])
    h = jax.nn.silu(gate) * up
    oe = jnp.einsum("bsef,efd->bsed", h, p["wo"])
    ref = jnp.zeros_like(x)
    for kk in range(cfg.top_k):
        sel = jnp.take_along_axis(
            oe, ei[..., kk][..., None, None], axis=2)[:, :, 0, :]
        ref = ref + sel * gv[..., kk][..., None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)
    assert float(aux) > 0.0


def test_moe_capacity_drops_ride_residual():
    from repro.configs import get_config
    from repro.models import moe as M

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    cfg = cfg.scaled_down(capacity_factor=0.25)  # force drops
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out, _ = M.moe_apply(p, x, cfg, None, "t")
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# Recurrent blocks: chunked == sequential.
# ---------------------------------------------------------------------------


def test_mamba_chunked_equals_stepwise():
    from repro.configs import get_config
    from repro.models import ssm as S

    cfg = get_config("jamba-v0.1-52b", smoke=True)
    p = S.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model),
                          jnp.float32) * 0.1
    full = S.mamba_apply(p, x, cfg, None, "t", chunk=8)
    cache = S.mamba_cache_init(cfg, 2, jnp.float32)
    outs = []
    for t in range(24):
        o, cache = S.mamba_decode(p, x[:, t:t + 1], cache, cfg, None, "t")
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=1e-3,
                               rtol=1e-2)


def test_rwkv_scan_equals_stepwise():
    from repro.configs import get_config
    from repro.models import rwkv as R

    cfg = get_config("rwkv6-3b", smoke=True)
    p = R.rwkv_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.1
    full = R.rwkv_apply(p, x, cfg, None, "t")
    cache = R.rwkv_cache_init(cfg, 2, jnp.float32)
    outs = []
    for t in range(16):
        o, cache = R.rwkv_decode(p, x[:, t:t + 1], cache, cfg, None, "t")
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=1e-3,
                               rtol=1e-2)
