"""Train-loop integration: loss goes down, checkpoint/restart is bit-exact,
fault injection recovers, microbatching is gradient-equivalent."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import single_device_mesh
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.train import optim
from repro.train.loop import TrainConfig, run_training


def _data_cfg(cfg, seq=64, gb=4):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=gb,
                      frontend=cfg.frontend, frontend_dim=cfg.frontend_dim,
                      frontend_tokens=cfg.frontend_tokens,
                      encdec=cfg.is_encdec, seed=3)


@pytest.mark.slow
def test_loss_decreases():
    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = single_device_mesh()
    tc = TrainConfig(steps=30, log_every=1,
                     optimizer=optim.AdamWConfig(lr=3e-3, warmup_steps=5,
                                                 total_steps=30))
    result = run_training(cfg, mesh, tc, _data_cfg(cfg))
    losses = list(result.losses.values())
    assert losses[-1] < losses[0] - 0.5, losses


@pytest.mark.slow
def test_checkpoint_restart_bit_exact(tmp_path):
    """train 10 straight == train 5, crash, resume 5 (same data, same opt)."""
    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = single_device_mesh()
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")

    opt = optim.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    r1 = run_training(cfg, mesh, TrainConfig(
        steps=10, log_every=1, ckpt_every=100, ckpt_dir=d1, optimizer=opt),
        _data_cfg(cfg))

    tc2 = TrainConfig(steps=5, log_every=1, ckpt_every=5, ckpt_dir=d2,
                      optimizer=opt)
    run_training(cfg, mesh, tc2, _data_cfg(cfg))
    tc3 = TrainConfig(steps=10, log_every=1, ckpt_every=100, ckpt_dir=d2,
                      optimizer=opt)
    r3 = run_training(cfg, mesh, tc3, _data_cfg(cfg))
    assert r3.restored_from == 5
    # same final loss trajectory
    assert r1.losses[9] == pytest.approx(r3.losses[9], rel=1e-5)


@pytest.mark.slow
def test_fault_injection_then_resume(tmp_path):
    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = single_device_mesh()
    d = str(tmp_path / "ck")
    opt = optim.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=12)

    class Bomb(Exception):
        pass

    def inject(step):
        if step == 7:
            raise Bomb("simulated node failure")

    with pytest.raises(Bomb):
        run_training(cfg, mesh, TrainConfig(
            steps=12, ckpt_every=3, ckpt_dir=d, optimizer=opt),
            _data_cfg(cfg), hooks={"inject_fault": inject})
    # supervisor behavior: reload and continue to completion
    r = run_training(cfg, mesh, TrainConfig(
        steps=12, ckpt_every=3, ckpt_dir=d, optimizer=opt), _data_cfg(cfg))
    assert r.restored_from == 6
    assert r.final_step == 12


@pytest.mark.slow
def test_microbatching_gradient_equivalent():
    """k microbatches give the same update as one fused batch (mean grad).

    bf16 param-cast disabled so the comparison is exact up to f32
    accumulation order (the cast itself is covered by smoke tests)."""
    cfg1 = get_config("llama3.2-1b", smoke=True).scaled_down(
        bf16_cast_params=False)
    cfg4 = cfg1.scaled_down(n_microbatches=4)
    ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=0)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg1)
    opt_state = optim.init(params)
    batch = {
        "tokens": jax.random.randint(key, (8, 32), 0, cfg1.vocab_size),
        "labels": jax.random.randint(key, (8, 32), 0, cfg1.vocab_size),
        "mask": jnp.ones((8, 32), jnp.float32),
    }
    from repro.models import lm as lm_mod

    def mean_grad(k):
        if k == 1:
            return jax.grad(lambda p: lm_mod.loss_fn(p, cfg1, batch))(params)
        micro = jax.tree.map(
            lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)
        gs = [jax.grad(lambda p: lm_mod.loss_fn(
            p, cfg1, jax.tree.map(lambda t: t[i], micro)))(params)
            for i in range(k)]
        return jax.tree.map(lambda *g: sum(g) / k, *gs)

    g1, g4 = mean_grad(1), mean_grad(4)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        # compute dtype is bf16, so per-microbatch product rounding
        # bounds the agreement at bf16 granularity
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=5e-3)
    # the fused step and the scan-accumulated step agree on the loss
    _, _, m1 = make_train_step(cfg1, ocfg)(params, opt_state, batch)
    _, _, m4 = make_train_step(cfg4, ocfg)(params, optim.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)


@pytest.mark.slow
def test_straggler_watchdog():
    import time

    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = single_device_mesh()

    def slow(step):
        if step == 15:
            time.sleep(1.0)

    r = run_training(cfg, mesh, TrainConfig(
        steps=18, optimizer=optim.AdamWConfig(warmup_steps=0)),
        _data_cfg(cfg), hooks={"inject_fault": slow})
    assert r.straggler_events >= 1
