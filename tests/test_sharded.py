"""Multi-device tests (8 forced host devices, subprocess-isolated so the
main pytest process keeps its single-device view).

Covers: sharding rules, distributed collectives (EF-compressed psum, ring
all-gather matmul, split-K decode attention), and a 2x4-mesh train step.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs import get_config
from repro.dist.collectives import (
    ef_compressed_psum, ring_ag_matmul, splitk_decode_attention)
from repro.dist.sharding import param_sharding, cache_sharding
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_step, input_specs
from repro.models import lm
from repro.train import optim

mesh = make_mesh((2, 4))
assert len(jax.devices()) == 8

# ---- sharding rules -------------------------------------------------------
cfg = get_config("llama3.2-1b", smoke=True).scaled_down(
    d_model=256, d_ff=1024, vocab_size=2048, n_heads=8,
    n_kv_heads=4, head_dim=32)
params = lm.init_params(jax.random.PRNGKey(0), cfg)
sh = param_sharding(params, mesh)
flat = jax.tree_util.tree_flatten_with_path(sh)[0]
specs = {"/".join(str(getattr(k, 'key', k)) for k in p): s.spec
         for p, s in flat}
# big 2D weights must be sharded on at least one axis
wi = [s for n, s in specs.items() if n.endswith("wi")]
assert any(any(ax is not None for ax in s) for s in wi), specs

# ---- EF-compressed psum ---------------------------------------------------
def psum_fn(x, err):
    return ef_compressed_psum(x, err, "data")

xs = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
errs = jnp.zeros((8, 64))
f = shard_map(psum_fn, mesh=mesh, in_specs=(P(("data", "model")), P(("data", "model"))),
              out_specs=(P(("data", "model")), P(("data", "model"))))
total, new_err = f(xs, errs)
# rows are laid out (data, model): psum over 'data' sums rows m and m+4;
# every data shard then holds that sum.
exact = xs[0:4] + xs[4:8]
got = total[0:4]
rel = float(jnp.abs(got - exact).max() / jnp.abs(exact).max())
assert rel < 0.05, rel   # int8 quantized all-reduce
# error feedback: residual bounded by one quantization step
assert float(jnp.abs(new_err).max()) < float(jnp.abs(xs).max()) / 64

# ---- ring all-gather matmul ------------------------------------------------
w = jax.random.normal(jax.random.PRNGKey(2), (16, 8))
x = jax.random.normal(jax.random.PRNGKey(3), (8, 16))   # rows sharded by 4
ring = shard_map(lambda xs, w: ring_ag_matmul(xs, w, "model"),
                 mesh=mesh, in_specs=(P("model", None), P(None, None)),
                 out_specs=P(None, None), check_rep=False)
out = ring(x, w)
np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), rtol=1e-4)

# ---- split-K decode attention ----------------------------------------------
B, S, H, D = 2, 32, 4, 16
q = jax.random.normal(jax.random.PRNGKey(4), (B, H, D))
k = jax.random.normal(jax.random.PRNGKey(5), (B, S, H, D))
v = jax.random.normal(jax.random.PRNGKey(6), (B, S, H, D))
valid = jnp.ones((B, S), bool)
fk = shard_map(lambda q, k, v, m: splitk_decode_attention(q, k, v, m, "model"),
               mesh=mesh,
               in_specs=(P(), P(None, "model"), P(None, "model"), P(None, "model")),
               out_specs=P(), check_rep=False)
out = fk(q, k, v, valid)
scores = jnp.einsum("bhd,bshd->bhs", q, k) * (D ** -0.5)
ref = jnp.einsum("bhs,bshd->bhd", jax.nn.softmax(scores, -1), v)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)

# ---- 2x4 mesh train step ----------------------------------------------------
from repro.configs import ShapeCell
cell = ShapeCell("t", 64, 8, "train")
ocfg = optim.AdamWConfig()
specs_in = input_specs(cfg, cell, mesh, ocfg)
step_fn = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
with mesh:
    params = jax.jit(lambda k: lm.init_params(k, cfg),
                     out_shardings=jax.tree.map(lambda a: a.sharding,
                                                specs_in["params"]))(
        jax.random.PRNGKey(0))
    opt_state = optim.init(params)
    batch = {
        "tokens": jnp.zeros((8, 64), jnp.int32),
        "labels": jnp.zeros((8, 64), jnp.int32),
        "mask": jnp.ones((8, 64), jnp.float32),
    }
    batch = {k: jax.device_put(v, NamedSharding(mesh, P("data")))
             for k, v in batch.items()}
    p2, s2, metrics = step_fn(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))

# ---- decode on sharded cache -------------------------------------------------
cache = lm.init_cache(cfg, 8, 64)
cs = cache_sharding(jax.eval_shape(lambda: lm.init_cache(cfg, 8, 64)), mesh, batch=8)
with mesh:
    cache = jax.tree.map(lambda c, s: jax.device_put(c, s), cache, cs)
    logits, cache = jax.jit(
        lambda p, c, tok, t: lm.decode_step(p, cfg, tok, c, t))(
        p2, cache, jnp.zeros((8,), jnp.int32), jnp.int32(3))
    assert np.isfinite(np.asarray(logits, np.float32)).all()

# ---- continuous-batching engine on the 2x4 mesh ------------------------------
from repro.serve.engine import Engine, Request

def serve(mesh_arg):
    rng2 = np.random.default_rng(7)
    reqs = [Request(prompt=list(rng2.integers(1, cfg.vocab_size, size=int(n))),
                    max_new_tokens=int(m))
            for n, m in zip(rng2.integers(2, 9, size=10),
                            rng2.integers(1, 5, size=10))]
    eng = Engine(cfg, jax.device_get(p2), max_seq=32, batch_size=8,
                 mesh=mesh_arg)   # p2: post-step params (params was donated)
    stats = eng.generate(reqs)
    nt = eng.n_traces()["decode"]
    assert nt == -1 or 1 <= nt <= 4, eng.n_traces()
    return [r.generated for r in reqs]

sharded_out = serve(mesh)
assert sharded_out == serve(None), (sharded_out, serve(None))

print("SHARDED-OK")
"""


@pytest.mark.slow
def test_sharded_suite(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "sharded_check.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script), src],
                       capture_output=True, text=True, timeout=1200, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "SHARDED-OK" in r.stdout
