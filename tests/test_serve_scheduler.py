"""Scheduler / paged-cache-pool unit and property tests (host-side).

The scheduling layer is pure Python (no jax in the decision path), so these
tests drive admission, bucketed decode-lane construction and the page/state
free lists directly through the public API — never by poking engine
internals — across randomized admit/finish interleavings.
"""
import numpy as np
import jax
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.configs import get_config
from repro.serve.cache import PagedCachePool, PrefixCache, default_page_size
from repro.serve.scheduler import (Request, RequestStats, Scheduler,
                                   decode_widths_for, prompt_buckets_for)


def _mk_req(rid, plen=4, arrival=0.0):
    r = Request(prompt=list(range(1, plen + 1)), max_new_tokens=4)
    r.stats = RequestStats(rid=rid, prompt_len=plen, arrival_s=arrival)
    r.generated = []
    return r


def test_decode_width_ladder():
    assert decode_widths_for(1) == (1,)
    assert decode_widths_for(2) == (1, 2)
    assert decode_widths_for(64) == (1, 2, 4, 8, 16, 32, 64)
    # non-power-of-two slot counts top out at the exact slot count
    assert decode_widths_for(6) == (1, 2, 4, 6)
    # the prompt ladder shape is shared (anchored at MIN_BUCKET instead)
    assert prompt_buckets_for(64) == (8, 16, 32, 64)


def test_bucketed_lanes_smallest_cover():
    sched = Scheduler(64, 32)
    for i in range(5):
        sched.enqueue(_mk_req(i))
    admitted = sched.admit(now=0.0)
    assert [idx for idx, _ in admitted] == [0, 1, 2, 3, 4]
    for idx, _ in admitted:
        sched.prefill_done(idx, first_token=1)
    n_live, lanes = sched.decode_lanes()
    assert n_live == 5 and len(lanes) == 8          # smallest bucket >= 5
    assert lanes[:5] == [0, 1, 2, 3, 4]             # live lanes first
    # padding lanes are distinct free slots, never live ones
    assert sorted(set(lanes[5:])) == sorted(lanes[5:])
    assert not set(lanes[5:]) & set(lanes[:5])
    sched.finish(1)
    sched.finish(3)
    n_live, lanes = sched.decode_lanes()
    assert n_live == 3 and len(lanes) == 4          # shrinks with the load


def test_prefilling_slots_never_pad_decode():
    """A mid-prefill slot holds real partial state: it must neither decode
    nor serve as a padding lane."""
    sched = Scheduler(4, 32)
    for i in range(4):
        sched.enqueue(_mk_req(i))
    sched.admit(now=0.0)
    for i in (0, 1, 2):
        sched.prefill_done(i, first_token=1)        # slot 3 stays mid-prefill
    n_live, lanes = sched.decode_lanes()
    assert n_live == 3 and len(lanes) == 4
    assert lanes[:3] == [0, 1, 2]
    # no free slots left: the pad lane parks (None), it never borrows the
    # mid-prefill slot's rows
    assert lanes[3] is None
    assert sched.prefilling() == [3]


def test_prefilling_round_robin():
    sched = Scheduler(4, 32)
    for i in range(3):
        sched.enqueue(_mk_req(i))
    sched.admit(now=0.0)
    heads = [sched.prefilling()[0] for _ in range(6)]
    assert heads == [0, 1, 2, 0, 1, 2]              # fair chunk interleave


def test_admission_respects_arrival_trace():
    sched = Scheduler(4, 32)
    sched.enqueue(_mk_req(0, arrival=0.0))
    sched.enqueue(_mk_req(1, arrival=5.0))
    assert [i for i, _ in sched.admit(now=1.0)] == [0]
    assert sched.num_pending == 1 and sched.next_arrival_s == 5.0
    assert [i for i, _ in sched.admit(now=6.0)] == [1]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_no_slot_leaks_random_interleaving(seed):
    """Random admit / prefill_done / finish interleavings: every request is
    eventually seated exactly once, lanes never alias, and finished slots
    return to the free set (no leaks)."""
    rng = np.random.default_rng(seed)
    n_slots = int(rng.integers(1, 9))
    sched = Scheduler(n_slots, 32)
    total = int(rng.integers(5, 25))
    submitted = seated = finished = 0
    live = set()
    for step in range(200):
        if submitted < total and rng.random() < 0.5:
            sched.enqueue(_mk_req(submitted))
            submitted += 1
        for idx, _req in sched.admit(now=0.0):
            assert idx not in live
            live.add(idx)
            seated += 1
        for idx in list(sched.prefilling()):
            if rng.random() < 0.7:
                sched.prefill_done(idx, first_token=1)
        n_live, lanes = sched.decode_lanes()
        real = [x for x in lanes if x is not None]
        assert len(real) == len(set(real))          # no duplicate slot lanes
        assert len(lanes) in decode_widths_for(n_slots) or not lanes
        for idx in lanes[:n_live]:
            if rng.random() < 0.3:
                sched.finish(idx)
                live.discard(idx)
                finished += 1
        assert sched.num_active == len(live)
    # drain: everything seated finishes
    for i in range(n_slots):
        if sched.slots[i].active:
            sched.finish(i)
            finished += 1
    while sched.num_pending:
        for idx, _ in sched.admit(now=0.0):
            sched.prefill_done(idx, first_token=1)
            sched.finish(idx)
            seated += 1
            finished += 1
    assert seated == finished == submitted
    assert sched.num_active == 0


# -- paged pool -------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("llama3.2-1b", smoke=True).scaled_down(
        d_model=64, d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
        head_dim=16)


def test_default_page_size():
    assert default_page_size(512) == 64
    assert default_page_size(48) == 16
    assert default_page_size(24) == 8


def test_pool_tables_and_free_lists(tiny_cfg):
    pool = PagedCachePool(tiny_cfg, n_slots=4, max_seq=32, page_size=8,
                          snapshot_slots=2)
    pps = pool.pages_per_slot
    assert pps == 4
    # slot rows + parking rows + snapshot region are disjoint
    slot_pages = set(pool.page_table.ravel().tolist())
    park = set(pool.parking_pages.tolist())
    free = set(pool._free_pages)
    assert len(slot_pages) == 4 * pps
    assert not slot_pages & park and not (slot_pages | park) & free
    assert pool.n_free_pages >= 2 * pps             # snapshot region
    assert pool.parking_state not in set(pool.state_table.tolist())

    # snapshot allocate / restore / release round-trips the free lists
    before = (pool.n_free_pages, pool.n_free_states)
    h = pool.take_snapshot(1, n_pages=2)
    assert h is not None
    assert pool.n_free_pages == before[0] - 2
    assert pool.n_free_states == before[1] - 1
    pool.restore_snapshot(3, h)                     # copy back, no alloc
    assert (pool.n_free_pages, pool.n_free_states) == (before[0] - 2,
                                                       before[1] - 1)
    pool.release_snapshot(h)
    assert (pool.n_free_pages, pool.n_free_states) == before

    # exhaustion returns None instead of corrupting rows
    handles = []
    while (h := pool.take_snapshot(0, n_pages=2)) is not None:
        handles.append(h)
    assert pool.take_snapshot(0, n_pages=2) is None
    for h in handles:
        pool.release_snapshot(h)
    assert (pool.n_free_pages, pool.n_free_states) == before


def test_lane_rows_parking(tiny_cfg):
    pool = PagedCachePool(tiny_cfg, n_slots=4, max_seq=32, page_size=8)
    prows, srows = pool.lane_rows([2, None, 0, None])
    assert prows.shape == (4, pool.pages_per_slot)
    np.testing.assert_array_equal(prows[0], pool.page_table[2])
    np.testing.assert_array_equal(prows[1], pool.parking_pages)
    assert srows.tolist() == [pool.state_table[2], pool.parking_state,
                              pool.state_table[0], pool.parking_state]


def test_pool_copy_semantics(tiny_cfg):
    """Snapshots are copies: mutating the source slot after take_snapshot
    must not leak into a later restore (copy-on-reference both ways)."""
    pool = PagedCachePool(tiny_cfg, n_slots=2, max_seq=32, page_size=8,
                          snapshot_slots=1)

    def poke(slot, value):
        rows = pool.page_table[slot]
        srow = pool.state_table[slot]
        def leaf(path, p):
            import jax.numpy as jnp
            from repro.serve.cache import is_paged_leaf
            if is_paged_leaf(path):
                return p.at[:, rows].set(value)
            return p.at[:, srow].set(value)
        pool.pools = jax.tree_util.tree_map_with_path(leaf, pool.pools)

    def read_page0(slot):
        leaves = jax.tree_util.tree_leaves_with_path(pool.pools)
        from repro.serve.cache import is_paged_leaf
        for path, p in leaves:
            if is_paged_leaf(path):
                return float(np.asarray(p[0, pool.page_table[slot][0]]).ravel()[0])
        raise AssertionError("no paged leaf")

    poke(0, 3.0)
    h = pool.take_snapshot(0, n_pages=2)
    poke(0, 7.0)                                    # source diverges
    pool.restore_snapshot(1, h)
    assert read_page0(0) == 7.0
    assert read_page0(1) == 3.0                     # snapshot-time contents


def test_prefix_cache_lru_and_boundaries(tiny_cfg):
    pool = PagedCachePool(tiny_cfg, n_slots=1, max_seq=32, page_size=8,
                          snapshot_slots=2)
    pfx = PrefixCache(pool, align=8, max_entries=2)
    assert pfx.boundary_for(5) == 0                 # shorter than align
    assert pfx.boundary_for(8) == 0                 # one token must remain
    assert pfx.boundary_for(9) == 8
    assert pfx.boundary_for(17) == 16
    p1, p2, p3 = ([1] * 24, [2] * 24, [3] * 24)
    pfx.store(0, p1, 8)
    pfx.store(0, p2, 8)
    assert pfx.lookup(p1) == (8, True)              # p1 now most-recent
    pfx.store(0, p3, 8)                             # evicts p2 (LRU)
    assert pfx.lookup(p2) == (0, False)
    assert pfx.lookup(p1) == (8, True)
    assert pfx.lookup(p3) == (8, True)
    assert len(pfx) == 2
    assert pfx.stats()["hits"] == 3 and pfx.stats()["misses"] == 1
    # longest-prefix match: a prompt sharing only the first 8 tokens of a
    # 16-deep entry falls back to the shorter boundary (fresh pool — the one
    # above has spent its snapshot rows on p1/p3)
    pool2 = PagedCachePool(tiny_cfg, n_slots=1, max_seq=32, page_size=8,
                           snapshot_slots=2)
    pfx2 = PrefixCache(pool2, align=8, max_entries=2)
    pfx2.store(0, p1, 16)
    assert pfx2.lookup(p1[:8] + [9] * 16) == (0, False)
    assert pfx2.lookup(p1[:16] + [9] * 8) == (16, True)
