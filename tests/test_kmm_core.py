"""Core KMM algorithm tests: exactness (incl. property-based), digit
bookkeeping, Algorithm-5 accumulation, and the precision-scalable dispatch
rule — the paper's Algorithms 1-5 and Section IV-C."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    digit_split, kmm_n, ksm_n, ksmm, max_exact_k, mm_n, preaccum_matmul,
    select_mode, sm_n,
)
from repro.core.dispatch import (
    Mode, conv_mults_per_product, efficiency_roof, kmm_levels_needed,
)


def _rand(rng, lo, hi, shape):
    return rng.integers(lo, hi, size=shape).astype(np.int32)


@pytest.mark.parametrize("w,n", [(8, 1), (8, 2), (12, 2), (14, 2), (12, 4),
                                 (16, 2), (16, 4)])
@pytest.mark.parametrize("signed", [False, True])
def test_kmm_mm_exact(w, n, signed):
    rng = np.random.default_rng(w * 100 + n + signed)
    k = min(max_exact_k(w), 96)
    if k < 1:
        pytest.skip("w too wide for int32-exact output")
    lo, hi = (-(2 ** (w - 1)), 2 ** (w - 1)) if signed else (0, 2**w)
    a = _rand(rng, lo, hi, (17, k))
    b = _rand(rng, lo, hi, (k, 23))
    ref = a.astype(np.int64) @ b.astype(np.int64)
    for fn in (kmm_n, mm_n):
        out = np.asarray(fn(jnp.array(a), jnp.array(b), w=w, n=n))
        np.testing.assert_array_equal(out.astype(np.int64), ref,
                                      err_msg=f"{fn.__name__} w={w} n={n}")


@pytest.mark.parametrize("w,n", [(8, 2), (12, 2), (16, 4), (31, 2)])
def test_scalar_algorithms_exact(w, n):
    rng = np.random.default_rng(n)
    w_eff = min(w, 15)  # elementwise products must fit int32
    a = _rand(rng, 0, 2**w_eff, (64,))
    b = _rand(rng, 0, 2**w_eff, (64,))
    ref = a.astype(np.int64) * b.astype(np.int64)
    for fn in (sm_n, ksm_n):
        out = np.asarray(fn(jnp.array(a), jnp.array(b), w=w_eff, n=n))
        np.testing.assert_array_equal(out.astype(np.int64), ref)


def test_ksmm_matches_matmul():
    rng = np.random.default_rng(0)
    a = _rand(rng, -2**11, 2**11, (6, 16))
    b = _rand(rng, -2**11, 2**11, (16, 5))
    out = np.asarray(ksmm(jnp.array(a), jnp.array(b), w=12, n=2))
    np.testing.assert_array_equal(out.astype(np.int64),
                                  a.astype(np.int64) @ b.astype(np.int64))


def test_digit_split_identity():
    rng = np.random.default_rng(1)
    x = rng.integers(-2**15, 2**15, size=(128,)).astype(np.int32)
    for h in (4, 7, 8):
        hi, lo = digit_split(jnp.array(x), h)
        recon = (np.asarray(hi).astype(np.int64) << h) + np.asarray(lo)
        np.testing.assert_array_equal(recon, x)
        assert (np.asarray(lo) >= 0).all() and (np.asarray(lo) < 2**h).all()


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(
    w=st.integers(4, 14),
    n=st.sampled_from([1, 2, 4]),
    m_dim=st.integers(1, 8),
    k_dim=st.integers(1, 32),
    n_dim=st.integers(1, 8),
    signed=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_kmm_exact(w, n, m_dim, k_dim, n_dim, signed, seed):
    """Property: KMM == exact integer matmul for any shape/width/digits
    within the int32-exactness envelope."""
    if max_exact_k(w) < k_dim:
        k_dim = max_exact_k(w)
    rng = np.random.default_rng(seed)
    lo, hi = (-(2 ** (w - 1)), 2 ** (w - 1)) if signed else (0, 2**w)
    a = _rand(rng, lo, hi, (m_dim, k_dim))
    b = _rand(rng, lo, hi, (k_dim, n_dim))
    out = np.asarray(kmm_n(jnp.array(a), jnp.array(b), w=w, n=n))
    np.testing.assert_array_equal(
        out.astype(np.int64), a.astype(np.int64) @ b.astype(np.int64))


@settings(max_examples=25, deadline=None)
@given(p=st.sampled_from([1, 2, 4, 8]), groups=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_property_alg5_preaccum_bit_exact(p, groups, seed):
    """Algorithm 5's two-level accumulation is bit-identical to flat
    accumulation for integers (the hardware saving is free of error)."""
    rng = np.random.default_rng(seed)
    k = p * groups
    a = _rand(rng, -2**7, 2**7, (5, k))
    b = _rand(rng, -2**7, 2**7, (k, 7))
    out = np.asarray(preaccum_matmul(jnp.array(a), jnp.array(b), p=p))
    np.testing.assert_array_equal(out.astype(np.int64),
                                  a.astype(np.int64) @ b.astype(np.int64))


class TestDispatch:
    """Paper Section IV-C mode windows for m=8."""

    def test_mode_windows(self):
        for w in range(1, 9):
            assert select_mode(w, 8).mode is Mode.MM1
        for w in range(9, 15):
            assert select_mode(w, 8).mode is Mode.KMM2
        for w in (15, 16):
            assert select_mode(w, 8).mode is Mode.MM2

    def test_pass_counts(self):
        assert select_mode(8, 8).passes == 1
        assert select_mode(12, 8).passes == 3
        assert select_mode(16, 8).passes == 4

    def test_kmm2_upper_bound_is_2m_minus_2(self):
        # the A_s digits need m bits: exactly the paper's w <= 2m-2 rule
        assert select_mode(14, 8).mode is Mode.KMM2
        assert select_mode(15, 8).mode is Mode.MM2

    def test_efficiency_roofs(self):
        # Fig. 11: roof 4/3 inside the KMM2 window, 1 elsewhere
        assert efficiency_roof(8, 8) == 1.0
        assert efficiency_roof(12, 8) == pytest.approx(4 / 3)
        assert efficiency_roof(14, 8) == pytest.approx(4 / 3)
        assert efficiency_roof(16, 8) == 1.0

    def test_conv_mults(self):
        # Eq. 13: 4**ceil(log2(ceil(w/m)))
        assert conv_mults_per_product(8, 8) == 1
        assert conv_mults_per_product(16, 8) == 4
        assert conv_mults_per_product(32, 8) == 16

    def test_recursion_depth(self):
        assert kmm_levels_needed(12, 8) == 1
        assert kmm_levels_needed(28, 8) == 3  # +1 carry growth per level


def test_max_exact_k():
    assert max_exact_k(8) == 2**15
    assert max_exact_k(14) == 2**3
    assert max_exact_k(16) == 0


@pytest.mark.parametrize("w", [11, 12, 13, 14])
def test_max_exact_k_boundary_brute_force(w):
    """For w >= 11 the bound K = 2**(31-2w) is tight: all-max unsigned w-bit operands
    are exact at K for both KMM and MM (the Karatsuba ``cs - c1 - c0``
    branch is dominated by the recombined output, see ``max_exact_k``), and
    KMM at K+1 overflows the int32 carrier."""
    k = max_exact_k(w)
    hi = 2**w - 1

    def worst(kk):
        a = np.full((3, kk), hi, np.int32)
        b = np.full((kk, 2), hi, np.int32)
        return a, b

    a, b = worst(k)
    ref = a.astype(np.int64) @ b.astype(np.int64)
    assert ref.max() < 2**31            # the bound's whole claim
    for fn in (kmm_n, mm_n):
        out = np.asarray(fn(jnp.array(a), jnp.array(b), w=w, n=2))
        np.testing.assert_array_equal(out.astype(np.int64), ref,
                                      err_msg=f"{fn.__name__} w={w} K={k}")
    # random operands at the boundary K are exact too
    rng = np.random.default_rng(w)
    a = _rand(rng, 0, 2**w, (5, k))
    b = _rand(rng, 0, 2**w, (k, 4))
    ref = a.astype(np.int64) @ b.astype(np.int64)
    out = np.asarray(kmm_n(jnp.array(a), jnp.array(b), w=w, n=2))
    np.testing.assert_array_equal(out.astype(np.int64), ref)
    # K+1 overflows: the true product exceeds int32 and the carrier wraps
    a, b = worst(k + 1)
    ref = a.astype(np.int64) @ b.astype(np.int64)
    assert ref.max() >= 2**31
    out = np.asarray(kmm_n(jnp.array(a), jnp.array(b), w=w, n=2))
    assert not np.array_equal(out.astype(np.int64), ref)


def test_kmm_float_combine_close():
    rng = np.random.default_rng(3)
    a = _rand(rng, -2**13, 2**13, (32, 512))
    b = _rand(rng, -2**13, 2**13, (512, 32))
    out = np.asarray(kmm_n(jnp.array(a), jnp.array(b), w=14, n=2,
                           combine_dtype=jnp.float32))
    ref = (a.astype(np.int64) @ b.astype(np.int64)).astype(np.float64)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert rel < 1e-6
