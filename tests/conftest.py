import os
import sys

# Tests run against the source tree; keep device count at 1 here (the
# dry-run sets its own XLA_FLAGS in-process — see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# Make tests/_hypothesis_compat.py importable regardless of pytest import
# mode / invocation directory.
sys.path.insert(0, os.path.dirname(__file__))
