"""Chunked-prefill exactness: resumed prefill chunks == single-shot prefill.

Model layer: splitting a prompt into ``start=``-resumed chunks must be
*bit-exact* against one single-shot ragged prefill — logits at the last
real token and every cache leaf — for all three block types (attention,
mamba, rwkv).  Chunk widths are multiples of ``SSM_PREFILL_GRID`` so the
mamba associative-scan windows align to absolute positions regardless of
where the chunk boundaries fall.

Engine layer: serving with ``prefill_chunk=`` (and with ``prefix_cache=``
hits restoring a mid-prompt snapshot) is token-identical to the unchunked
engine, which PR-2 already pinned to sequential single-request generation.

Boundary cases follow the issue checklist: prompt lengths 1, C-1, C, C+1
around the chunk size C.
"""
import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Engine, Request

CHUNK = 8   # == lm.SSM_PREFILL_GRID: the smallest legal serve chunk


def _cfg(arch):
    return get_config(arch, smoke=True).scaled_down(
        d_model=64, d_ff=128, vocab_size=256)


def _chunked_vs_single(arch, plen, max_seq=32):
    cfg = _cfg(arch)
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(plen)
    prompt = rng.integers(1, 250, size=plen).astype(np.int32)

    def ragged_call(cache, toks, start, take):
        width = toks.shape[1]
        mask = np.zeros((1, width), bool)
        mask[0, :take] = True
        last = np.array([take - 1], np.int32)
        return lm.prefill(params, cfg, jax.numpy.asarray(toks), cache,
                          pad_mask=jax.numpy.asarray(mask),
                          last_idx=jax.numpy.asarray(last),
                          start=jax.numpy.int32(start))

    # single shot: one ragged call over the bucketed prompt width
    width = CHUNK
    while width < plen:
        width *= 2
    toks = np.zeros((1, width), np.int32)
    toks[0, :plen] = prompt
    logits_ref, cache_ref, _ = ragged_call(
        lm.init_cache(cfg, 1, max_seq), toks, 0, plen)

    # chunked: resume every CHUNK tokens
    cache = lm.init_cache(cfg, 1, max_seq)
    off = 0
    while off < plen:
        take = min(CHUNK, plen - off)
        toks = np.zeros((1, CHUNK), np.int32)
        toks[0, :take] = prompt[off:off + take]
        logits, cache, _ = ragged_call(cache, toks, off, take)
        off += take

    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits_ref))
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(cache),
            jax.tree_util.tree_leaves_with_path(cache_ref)):
        name = jax.tree_util.keystr(path)
        if "k" in name.lower() or "v" in name.lower():
            # KV rows beyond the prompt are never read (kv_valid masks by
            # absolute position); compare the written region only
            np.testing.assert_array_equal(
                np.asarray(a)[:, :, :plen], np.asarray(b)[:, :, :plen],
                err_msg=f"{arch} plen={plen} leaf={name}")
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{arch} plen={plen} "
                                                  f"leaf={name}")


@pytest.mark.parametrize("plen", [1, CHUNK - 1, CHUNK, CHUNK + 1,
                                  2 * CHUNK, 3 * CHUNK + 3])
def test_chunked_prefill_bitexact_attn(plen):
    _chunked_vs_single("llama3.2-1b", plen)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "rwkv6-3b"])
@pytest.mark.parametrize("plen", [1, CHUNK - 1, CHUNK, CHUNK + 1,
                                  3 * CHUNK + 3])
def test_chunked_prefill_bitexact_recurrent(arch, plen):
    _chunked_vs_single(arch, plen)


# -- engine level -----------------------------------------------------------


def _serve(cfg, params, prompts, batch_size=4, **kw):
    eng = Engine(cfg, params, max_seq=48, batch_size=batch_size, **kw)
    reqs = [Request(prompt=list(p), max_new_tokens=5,
                    temperature=0.8 if i % 2 else 0.0)
            for i, p in enumerate(prompts)]
    eng.generate(reqs)
    return [r.generated for r in reqs], eng


@pytest.fixture(scope="module")
def tiny():
    cfg = _cfg("llama3.2-1b")
    return cfg, lm.init_params(jax.random.PRNGKey(7), cfg)


def test_engine_chunked_prefill_token_identity(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, 250, size=n))
               for n in (3, 17, 24, 9, 24, 30)]
    base, _ = _serve(cfg, params, prompts)
    for chunk in (8, 16):
        got, eng = _serve(cfg, params, prompts, prefill_chunk=chunk)
        assert got == base, chunk
        # chunk widths trace at most the chunk ladder, decode widths at
        # most the slot ladder — no per-prompt-length retraces
        nt = eng.n_traces()
        assert nt["prefill"] == -1 or nt["prefill"] <= len(
            [b for b in (8, 16) if b <= chunk])


def test_engine_prefix_cache_token_identity(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(1)
    shared = list(rng.integers(1, 250, size=20))
    prompts = [shared + list(rng.integers(1, 250, size=k))
               for k in (4, 7, 2, 9)]
    # 2 slots so requests 2/3 are admitted after request 0's snapshot
    # exists (a full-width admission wave would all miss together)
    base, _ = _serve(cfg, params, prompts, batch_size=2)
    got, eng = _serve(cfg, params, prompts, batch_size=2, prefill_chunk=8,
                      prefix_cache=True)
    assert got == base
    st = eng.prefix.stats()
    # requests admitted after the first snapshot restore it (the first
    # admission wave looks up before anything is stored)
    assert st["hits"] >= 2 and st["entries"] >= 1, st
    # a prefix hit skips recomputing the shared prefix: the restored
    # request resumes mid-prompt
    assert eng.pool.n_free_pages >= 0


def test_engine_prefix_cache_rejects_bad_chunk(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="prefill_chunk"):
        Engine(cfg, params, max_seq=48, batch_size=2, prefill_chunk=12)
    with pytest.raises(ValueError, match="prefill_chunk"):
        Engine(cfg, params, max_seq=48, batch_size=2, prefill_chunk=4)
    with pytest.raises(ValueError, match="page_size"):
        Engine(cfg, params, max_seq=48, batch_size=2, page_size=32)
