"""Fused single-pass kernel (kernels/fused_gemm.py): bit-identity against the
staged Pallas path and the ref.py oracle across hostile tile/padding combos,
the exact-int32 boundary, the grouped expert grid, and the dequant epilogue.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.dispatch import ExecPlan, analytic_plan, select_plan
from repro.core.kmm import max_exact_k
from repro.kernels import ops
from repro.kernels.fused_gemm import fused_gemm, fused_gemm_grouped
from repro.kernels.ref import ref_int_gemm_i64
from repro.quant.qmatmul import (
    prequant_matmul, quantized_matmul, quantized_matmul_batched,
)
from repro.tune import runner, space

# Non-multiple M/N/K, 1-row/1-col extremes, K-padding that exercises the
# z-correction on padded rows (split(0) = (0, -z) must cancel exactly).
HOSTILE_SHAPES = [(33, 70, 17), (1, 64, 1), (130, 70, 50)]
TILE_COMBOS = [(32, 32, 32), (64, 32, 64), (32, 64, 256)]


def _staged_variant(w: int, m: int = 8) -> str:
    return "mm1" if w <= m else "kmm2"


def _plans(w: int, tiles, combine_int32: bool):
    bm, bn, bk = tiles
    depth = 0 if w <= 8 else 1
    fused = ExecPlan("fused", w, backend="pallas", block_m=bm, block_n=bn,
                     block_k=bk, combine_int32=combine_int32, depth=depth)
    staged = ExecPlan(_staged_variant(w), w, backend="pallas", block_m=bm,
                      block_n=bn, block_k=bk, combine_int32=combine_int32,
                      depth=depth)
    return fused, staged


# ---------------------------------------------------------------------------
# Satellite: bit-identity vs the staged path + the ref.py oracle.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", [4, 8, 12, 14])
@pytest.mark.parametrize("mkn", HOSTILE_SHAPES)
def test_fused_bit_identical_to_staged_and_mirror(w, mkn):
    """Same tiles, same padding: the fused kernel must reproduce the staged
    Pallas pipeline AND the pure-jnp staged mirror bit-for-bit — fp32
    combine included (identical operation sequence, not a tolerance)."""
    a, b = runner.make_operands(mkn, w, seed=w)
    oracle = ref_int_gemm_i64(np.asarray(a), np.asarray(b))
    for tiles in TILE_COMBOS:
        fused, staged = _plans(w, tiles, combine_int32=w <= 8)
        out = np.asarray(ops.run_plan_jit(a, b, fused))
        np.testing.assert_array_equal(
            out, np.asarray(ops.run_plan_jit(a, b, staged)),
            err_msg=f"fused != staged at w={w} tiles={tiles}")
        np.testing.assert_array_equal(
            out, np.asarray(ops.run_plan_jit(a, b, fused,
                                             use_ref_kernels=True)),
            err_msg=f"fused != jnp mirror at w={w} tiles={tiles}")
        if fused.is_exact_int:
            np.testing.assert_array_equal(out.astype(np.int64), oracle)


def _i64_oracle_atol(w: int, k: int) -> float:
    """fp32-combine noise floor vs the int64 oracle: casting an int32 digit
    accumulator (|value| <= K * 2^(2w-2) per digit pair) to fp32 rounds at
    2^-24 relative; a few combine ops keep the error within a small
    multiple.  Real correction bugs (a dropped z*colsum or z^2*K term) sit
    orders of magnitude above this at the test shapes."""
    return max(1.0, k * 2.0 ** (2 * w) * 2.0 ** -24)


@pytest.mark.parametrize("w", [14, 15, 16])
@pytest.mark.parametrize("mkn", HOSTILE_SHAPES)
def test_fused_mm2_bit_identical_to_staged_and_mirror(w, mkn):
    """The single-pass MM2 boundary mode (w = 2m-1, 2m): fused_mm2 must
    reproduce the staged Pallas MM2 pipeline AND the pure-jnp mirror
    bit-for-bit, and sit at the fp32 noise floor of the int64 oracle.
    w=14 runs the 4-pass mode *inside* the KMM2 window — the mode is valid
    anywhere in (m, 2m], not just on the boundary."""
    a, b = runner.make_operands(mkn, w, seed=w)
    oracle = ref_int_gemm_i64(np.asarray(a), np.asarray(b))
    for tiles in TILE_COMBOS:
        bm, bn, bk = tiles
        fused = ExecPlan("fused_mm2", w, backend="pallas", block_m=bm,
                         block_n=bn, block_k=bk, depth=1)
        staged = ExecPlan("mm2", w, backend="pallas", block_m=bm,
                          block_n=bn, block_k=bk, depth=1)
        out = np.asarray(ops.run_plan_jit(a, b, fused))
        np.testing.assert_array_equal(
            out, np.asarray(ops.run_plan_jit(a, b, staged)),
            err_msg=f"fused_mm2 != staged mm2 at w={w} tiles={tiles}")
        np.testing.assert_array_equal(
            out, np.asarray(ops.run_plan_jit(a, b, fused,
                                             use_ref_kernels=True)),
            err_msg=f"fused_mm2 != jnp mirror at w={w} tiles={tiles}")
        np.testing.assert_allclose(
            out.astype(np.float64), oracle, rtol=0,
            atol=_i64_oracle_atol(w, mkn[1]),
            err_msg=f"fused_mm2 off the oracle at w={w} tiles={tiles}")


@pytest.mark.parametrize("w", [8, 12, 15, 20])
@pytest.mark.parametrize("mkn", HOSTILE_SHAPES)
def test_fused_depth2_bit_identical_to_staged_and_mirror(w, mkn):
    """Depth-2 fused recursion (9 MXU passes, nested Fig. 8 pre-adders in
    VMEM): bit-identical to the staged two-level plane pipeline and the
    jnp mirror, fp32-noise-close to the int64 oracle.  Depth 2 is forced
    below its analytic window too (w=8/12/15) — the nested split must be
    valid anywhere ``kmm_levels_needed(w, m) <= 2``."""
    a, b = runner.make_operands(mkn, w, seed=w)
    oracle = ref_int_gemm_i64(np.asarray(a), np.asarray(b))
    for tiles in TILE_COMBOS:
        bm, bn, bk = tiles
        fused = ExecPlan("fused", w, backend="pallas", block_m=bm,
                         block_n=bn, block_k=bk, depth=2)
        staged = ExecPlan("kmm2", w, backend="pallas", block_m=bm,
                          block_n=bn, block_k=bk, depth=2)
        out = np.asarray(ops.run_plan_jit(a, b, fused))
        np.testing.assert_array_equal(
            out, np.asarray(ops.run_plan_jit(a, b, staged)),
            err_msg=f"fused d2 != staged d2 at w={w} tiles={tiles}")
        np.testing.assert_array_equal(
            out, np.asarray(ops.run_plan_jit(a, b, fused,
                                             use_ref_kernels=True)),
            err_msg=f"fused d2 != jnp mirror at w={w} tiles={tiles}")
        np.testing.assert_allclose(
            out.astype(np.float64), oracle, rtol=0,
            atol=_i64_oracle_atol(w, mkn[1]),
            err_msg=f"fused d2 off the oracle at w={w} tiles={tiles}")


@pytest.mark.parametrize("w", [4, 8, 12, 14, 15, 16, 20])
def test_fused_pruned_space_candidates_pass_the_gate(w):
    """Every fused plan the pruned tune space emits must pass the runner's
    bit-exact correctness gate (the same gate the autotuner applies) —
    including the fused_mm2 boundary mode (w=15, 16) and fused depth-2
    (w=20)."""
    shape = (16, 32, 16)
    cands = [p for p in space.pruned_space(shape, w, backend="pallas",
                                           tile_choices=(32, 64))
             if p.variant in ("fused", "fused_mm2")]
    assert cands, f"no fused candidates at w={w}"
    if w in (15, 16):
        assert any(p.variant == "fused_mm2" for p in cands)
    if w == 20:
        assert any(p.depth == 2 for p in cands)
    a, b = runner.make_operands(shape, w, seed=w)
    for plan in cands:
        ok, err = runner.check_plan(plan, a, b)
        assert ok, (plan, err)


def test_fused_analytic_default_covers_windows():
    """backend='pallas' analytic dispatch: fused for MM1 + KMM2 windows,
    fused_mm2 on the (2m-2, 2m] boundary, fused depth-2 for 4-digit
    recursion; only depth >= 3 stays staged."""
    for w in (4, 8):
        plan = analytic_plan(w, backend="pallas")
        assert plan.variant == "fused" and plan.is_exact_int
    for w in (9, 12, 14):
        plan = analytic_plan(w, backend="pallas")
        assert plan.variant == "fused" and plan.depth == 1
    for w in (15, 16):
        plan = analytic_plan(w, backend="pallas")
        assert plan.variant == "fused_mm2" and plan.depth == 1
    for w in (17, 20, 26):
        plan = analytic_plan(w, backend="pallas")
        assert plan.variant == "fused" and plan.depth == 2
    assert analytic_plan(28, backend="pallas").variant == "kmm2"


# ---------------------------------------------------------------------------
# Satellite: exact-int32 mode at the max_exact_k boundary.
# ---------------------------------------------------------------------------


def test_fused_exact_int32_at_max_exact_k_boundary():
    w = 12
    k = max_exact_k(w)                       # 128: the tight int32 ceiling
    a, b = runner.make_operands((16, k, 16), w, seed=3)
    plan = ExecPlan("fused", w, backend="pallas", block_m=32, block_n=32,
                    block_k=32, combine_int32=True, depth=1)
    assert space.validate(plan, (16, k, 16)) is None
    out = np.asarray(ops.run_plan_jit(a, b, plan))
    assert out.dtype == np.int32
    np.testing.assert_array_equal(
        out.astype(np.int64),
        ref_int_gemm_i64(np.asarray(a), np.asarray(b)))
    # one past the boundary: the pruner must reject the plan, and the
    # int_gemm API must refuse an exact request outright
    assert space.validate(plan, (16, k + 1, 16)) is not None
    with pytest.raises(ValueError, match="max exact K"):
        ops.int_gemm(jnp.zeros((16, k + 1), jnp.int32),
                     jnp.zeros((k + 1, 16), jnp.int32),
                     w=w, backend="pallas", exact=True)


# ---------------------------------------------------------------------------
# Satellite: grouped expert grid vs a per-expert loop.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", [8, 12])
def test_fused_grouped_matches_per_expert_loop(w):
    e, c, k, n = 3, 10, 70, 9
    rng = np.random.default_rng(w)
    lim = 2 ** (w - 1)
    a = jnp.asarray(rng.integers(-lim, lim, (e, c, k)), jnp.int32)
    b = jnp.asarray(rng.integers(-lim, lim, (e, k, n)), jnp.int32)
    kw = dict(w=w, block_m=32, block_n=32, block_k=32)
    grouped = np.asarray(fused_gemm_grouped(a, b, **kw))
    for i in range(e):
        single = np.asarray(fused_gemm(a[i], b[i], **kw))
        np.testing.assert_array_equal(grouped[i], single,
                                      err_msg=f"expert {i} diverged")
        if w <= 8:
            np.testing.assert_array_equal(
                grouped[i].astype(np.int64),
                ref_int_gemm_i64(np.asarray(a[i]), np.asarray(b[i])))


def test_fused_grouped_dequant_epilogue():
    e, c, k, n = 2, 6, 33, 5
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-2048, 2048, (e, c, k)), jnp.int32)
    b = jnp.asarray(rng.integers(-2048, 2048, (e, k, n)), jnp.int32)
    sx = jnp.asarray(rng.random((e, c, 1)), jnp.float32)
    sw = jnp.asarray(rng.random((e, 1, n)), jnp.float32)
    kw = dict(w=12, block_m=32, block_n=32, block_k=32)
    out = np.asarray(fused_gemm_grouped(a, b, sx, sw, **kw))
    acc = np.asarray(fused_gemm_grouped(a, b, **kw))
    np.testing.assert_array_equal(out, acc * np.asarray(sx * sw))


@pytest.mark.parametrize("w", [8, 12])
def test_fused_grouped_ragged_counts_property(w):
    """Ragged contract: with (E, S) live counts and static seg, every live
    row is bit-identical to the dense per-expert result and every dead row
    is an exact zero — including experts with zero live tokens in a
    segment and a fully-dead expert.  Accumulation is untouched (output
    masking only), so liveness never changes a live row's bits."""
    e, seg, n_seg, k, n = 4, 8, 3, 70, 9
    c = seg * n_seg
    rng = np.random.default_rng(w)
    lim = 2 ** (w - 1)
    a = jnp.asarray(rng.integers(-lim, lim, (e, c, k)), jnp.int32)
    b = jnp.asarray(rng.integers(-lim, lim, (e, k, n)), jnp.int32)
    sx = jnp.asarray(rng.random((e, c, 1)), jnp.float32)
    sw = jnp.asarray(rng.random((e, 1, n)), jnp.float32)
    counts = jnp.asarray([[3, 8, 0],     # partial, full, empty segments
                          [0, 0, 0],     # fully-dead expert
                          [8, 8, 8],     # fully-live expert
                          [1, 0, 5]], jnp.int32)
    kw = dict(w=w, seg=seg, block_m=32, block_n=32, block_k=32)
    out = np.asarray(fused_gemm_grouped(a, b, sx, sw, counts=counts, **kw))
    dense = np.asarray(fused_gemm_grouped(
        a, b, sx, sw, w=w, block_m=32, block_n=32, block_k=32))
    live = (np.arange(c)[None, :] % seg
            < np.asarray(counts)[:, np.arange(c) // seg])       # (E, C)
    np.testing.assert_array_equal(
        out[live], dense[live], err_msg="live rows moved bits")
    np.testing.assert_array_equal(
        out[~live], np.zeros_like(out[~live]),
        err_msg="dead rows must be exact zeros")
    # raw-accumulator (no dequant) path honors the same contract
    acc = np.asarray(fused_gemm_grouped(a, b, counts=counts, **kw))
    acc_dense = np.asarray(fused_gemm_grouped(
        a, b, w=w, block_m=32, block_n=32, block_k=32))
    np.testing.assert_array_equal(acc[live], acc_dense[live])
    assert not acc[~live].any()


def test_quantized_batched_ragged_pallas_matches_xla():
    """The serve seam: quantized_matmul_batched with ragged counts must be
    token-identical between the pallas grouped kernel and the XLA
    fallback — dead rows are exact zeros on BOTH backends (the contract is
    backend-independent, so numerics pinning sees one class)."""
    rng = np.random.default_rng(11)
    e, c, k, n, seg = 3, 12, 32, 8, 4
    xb = jnp.asarray(rng.standard_normal((e, c, k)), jnp.float32)
    wb = jnp.asarray(rng.standard_normal((e, k, n)), jnp.float32)
    counts = jnp.asarray([[4, 0, 2], [0, 0, 0], [4, 4, 4]], jnp.int32)
    for w in (8, 12):
        xla = np.asarray(quantized_matmul_batched(
            xb, wb, w, 8, "auto", "xla", counts=counts, seg=seg))
        pal = np.asarray(quantized_matmul_batched(
            xb, wb, w, 8, "auto", "pallas", counts=counts, seg=seg))
        np.testing.assert_array_equal(xla, pal, err_msg=f"w={w}")
        live = (np.arange(c)[None, :] % seg
                < np.asarray(counts)[:, np.arange(c) // seg])
        assert not xla[~live].any() and not pal[~live].any()


# ---------------------------------------------------------------------------
# Satellite: dequant epilogue == staged dequant, exact fp32 equality.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", [8, 12])
def test_dequant_epilogue_equals_staged_dequant(w):
    m, k, n = 17, 70, 9
    a, b = runner.make_operands((m, k, n), w, seed=w)
    rng = np.random.default_rng(w)
    sx = jnp.asarray(rng.random((m, 1)), jnp.float32)
    sw = jnp.asarray(rng.random((1, n)), jnp.float32)
    kw = dict(w=w, block_m=32, block_n=32, block_k=64)
    fused = np.asarray(fused_gemm(a, b, sx, sw, **kw))
    acc = np.asarray(fused_gemm(a, b, **kw)).astype(np.float32)
    staged_dequant = acc * np.asarray(sx * sw)
    np.testing.assert_array_equal(fused, staged_dequant)


@pytest.mark.parametrize("w", [4, 8])
def test_quantized_matmul_pallas_bit_identical_to_xla_exact_class(w):
    """In the exact-int class (w <= m) the fused pallas route computes the
    same integer as the XLA dot, and the in-kernel epilogue multiplies the
    same scales in the same order — outputs are bit-identical."""
    rng = np.random.default_rng(w)
    x = jnp.asarray(rng.standard_normal((4, 16, 32)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
    xla = np.asarray(quantized_matmul(x, wm, w))
    pal = np.asarray(quantized_matmul(x, wm, w, 8, "auto", "pallas"))
    np.testing.assert_array_equal(xla, pal)
    # batched expert path, one grouped kernel launch
    xb = jnp.asarray(rng.standard_normal((3, 8, 32)), jnp.float32)
    wb = jnp.asarray(rng.standard_normal((3, 32, 8)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(quantized_matmul_batched(xb, wb, w)),
        np.asarray(quantized_matmul_batched(xb, wb, w, 8, "auto", "pallas")))


def test_quantized_matmul_pallas_w12_close_and_bf16():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    xla = np.asarray(quantized_matmul(x, wm, 12))
    pal = np.asarray(quantized_matmul(x, wm, 12, 8, "auto", "pallas"))
    denom = max(np.abs(xla).max(), 1.0)
    assert np.abs(xla - pal).max() / denom < 1e-6   # same value, fp32 class
    out = quantized_matmul(x.astype(jnp.bfloat16), wm, 12, 8, "auto",
                           "pallas")
    assert out.dtype == jnp.bfloat16                # epilogue casts in-kernel


def test_prequant_matmul_pallas_route():
    from repro.quant.policy import POLICY_W8
    from repro.quant.prequant import prequantize

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 5, 32)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((32, 12)), jnp.float32)
    rec = prequantize({"wi": wm}, POLICY_W8)["wi"]
    assert rec["q"].dtype == jnp.int8               # narrow storage carrier
    np.testing.assert_array_equal(
        np.asarray(prequant_matmul(x, rec, 8)),
        np.asarray(prequant_matmul(x, rec, 8, backend="pallas")))


def test_pallas_route_falls_back_outside_fused_windows():
    """w=28 needs depth-3 recursion (no fused kernel): the pallas backend
    must fall back to the XLA path, bit-identically.  w=16 — which used to
    fall back — now rides the fused_mm2 single pass."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(quantized_matmul(x, wm, 28)),
        np.asarray(quantized_matmul(x, wm, 28, 8, "auto", "pallas")))
    assert select_plan((4, 32, 8), 28, backend="pallas").variant == "kmm2"
    assert select_plan((4, 32, 8), 16, backend="pallas").variant \
        == "fused_mm2"


# ---------------------------------------------------------------------------
# Dispatch/tuning seam: fused plans stay in the staged fingerprint class.
# ---------------------------------------------------------------------------


def test_table_can_swap_fused_and_staged_without_moving_bits():
    """A tuning table recording a staged kmm2 winner is adopted over the
    fused analytic default (same fp32 fingerprint class + same K padding)
    and must not change a single output bit."""
    from repro.tune.table import TuningTable, use_table

    w, shape = 12, (64, 128, 64)
    a, b = runner.make_operands(shape, w, seed=1)
    base = np.asarray(ops.int_gemm(a, b, w=w, backend="pallas"))
    t = TuningTable()
    t.put("pallas", shape, w,
          ExecPlan("kmm2", w, backend="pallas", block_m=32, block_n=32,
                   block_k=256, combine_int32=False, depth=1))
    with use_table(t):
        plan = select_plan(shape, w, backend="pallas")
        assert plan.variant == "kmm2" and plan.source == "table"
        tabled = np.asarray(ops.int_gemm(a, b, w=w, backend="pallas"))
    np.testing.assert_array_equal(base, tabled)


def test_quantized_matmul_pallas_table_never_moves_bits():
    """Numerics pinning holds on the pallas backend too: a table that
    redirects the fused plan to a staged pallas plan (same fingerprint
    class) must leave quantized_matmul(backend='pallas') bit-identical —
    the redirect runs the staged kernel, never the XLA rounding class."""
    from repro.tune.table import TuningTable, use_table

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    t = TuningTable()
    t.put("pallas", (8, 256, 64), 12,
          ExecPlan("kmm2", 12, backend="pallas", block_m=32, block_n=64,
                   block_k=256, combine_int32=False, depth=1))
    for w in (8, 12):
        base = np.asarray(quantized_matmul(x, wm, w, 8, "auto", "pallas"))
        with use_table(t):
            tabled = np.asarray(quantized_matmul(x, wm, w, 8, "auto",
                                                 "pallas"))
        np.testing.assert_array_equal(base, tabled, err_msg=f"w={w}")


def test_pallas_route_actually_runs_fused_at_serve_shapes():
    """Tiny-M decode/prefill GEMMs must ride the fused kernel (clamped
    tiles), not silently fall back to XLA: in the fp32 class the pallas
    rounding differs from XLA's digit recursion at large K, which is
    observable — so assert the route by checking the pallas result equals
    the fused kernel's output computed directly."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)   # decode M=2
    wm = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    from repro.quant.qmatmul import _quantize, _shrink_tiles

    qx, sx = _quantize(x, 12, axis=-1)
    qw, sw = _quantize(wm, 12, axis=0)
    plan = _shrink_tiles(analytic_plan(12, backend="pallas"), (2, 64, 48))
    assert plan.tiles == (8, 64, 64)
    direct = np.asarray(fused_gemm(
        qx, qw, sx, sw, w=12, block_m=plan.block_m, block_n=plan.block_n,
        block_k=plan.block_k, out_dtype=jnp.float32))
    routed = np.asarray(quantized_matmul(x, wm, 12, 8, "auto", "pallas"))
    np.testing.assert_array_equal(routed, direct)
