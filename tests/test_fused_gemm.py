"""Fused single-pass kernel (kernels/fused_gemm.py): bit-identity against the
staged Pallas path and the ref.py oracle across hostile tile/padding combos,
the exact-int32 boundary, the grouped expert grid, and the dequant epilogue.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.dispatch import ExecPlan, analytic_plan, select_plan
from repro.core.kmm import max_exact_k
from repro.kernels import ops
from repro.kernels.fused_gemm import fused_gemm, fused_gemm_grouped
from repro.kernels.ref import ref_int_gemm_i64
from repro.quant.qmatmul import (
    prequant_matmul, quantized_matmul, quantized_matmul_batched,
)
from repro.tune import runner, space

# Non-multiple M/N/K, 1-row/1-col extremes, K-padding that exercises the
# z-correction on padded rows (split(0) = (0, -z) must cancel exactly).
HOSTILE_SHAPES = [(33, 70, 17), (1, 64, 1), (130, 70, 50)]
TILE_COMBOS = [(32, 32, 32), (64, 32, 64), (32, 64, 256)]


def _staged_variant(w: int, m: int = 8) -> str:
    return "mm1" if w <= m else "kmm2"


def _plans(w: int, tiles, combine_int32: bool):
    bm, bn, bk = tiles
    depth = 0 if w <= 8 else 1
    fused = ExecPlan("fused", w, backend="pallas", block_m=bm, block_n=bn,
                     block_k=bk, combine_int32=combine_int32, depth=depth)
    staged = ExecPlan(_staged_variant(w), w, backend="pallas", block_m=bm,
                      block_n=bn, block_k=bk, combine_int32=combine_int32,
                      depth=depth)
    return fused, staged


# ---------------------------------------------------------------------------
# Satellite: bit-identity vs the staged path + the ref.py oracle.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", [4, 8, 12, 14])
@pytest.mark.parametrize("mkn", HOSTILE_SHAPES)
def test_fused_bit_identical_to_staged_and_mirror(w, mkn):
    """Same tiles, same padding: the fused kernel must reproduce the staged
    Pallas pipeline AND the pure-jnp staged mirror bit-for-bit — fp32
    combine included (identical operation sequence, not a tolerance)."""
    a, b = runner.make_operands(mkn, w, seed=w)
    oracle = ref_int_gemm_i64(np.asarray(a), np.asarray(b))
    for tiles in TILE_COMBOS:
        fused, staged = _plans(w, tiles, combine_int32=w <= 8)
        out = np.asarray(ops.run_plan_jit(a, b, fused))
        np.testing.assert_array_equal(
            out, np.asarray(ops.run_plan_jit(a, b, staged)),
            err_msg=f"fused != staged at w={w} tiles={tiles}")
        np.testing.assert_array_equal(
            out, np.asarray(ops.run_plan_jit(a, b, fused,
                                             use_ref_kernels=True)),
            err_msg=f"fused != jnp mirror at w={w} tiles={tiles}")
        if fused.is_exact_int:
            np.testing.assert_array_equal(out.astype(np.int64), oracle)


@pytest.mark.parametrize("w", [4, 8, 12, 14])
def test_fused_pruned_space_candidates_pass_the_gate(w):
    """Every fused plan the pruned tune space emits must pass the runner's
    bit-exact correctness gate (the same gate the autotuner applies)."""
    shape = (16, 32, 16)
    cands = [p for p in space.pruned_space(shape, w, backend="pallas",
                                           tile_choices=(32, 64))
             if p.variant == "fused"]
    assert cands, f"no fused candidates at w={w}"
    a, b = runner.make_operands(shape, w, seed=w)
    for plan in cands:
        ok, err = runner.check_plan(plan, a, b)
        assert ok, (plan, err)


def test_fused_analytic_default_covers_windows():
    """backend='pallas' analytic dispatch: fused for MM1 + KMM2 windows,
    staged MM2 above, staged recursion for w > 16."""
    for w in (4, 8):
        plan = analytic_plan(w, backend="pallas")
        assert plan.variant == "fused" and plan.is_exact_int
    for w in (9, 12, 14):
        plan = analytic_plan(w, backend="pallas")
        assert plan.variant == "fused" and plan.depth == 1
    assert analytic_plan(15, backend="pallas").variant == "mm2"
    assert analytic_plan(16, backend="pallas").variant == "mm2"
    assert analytic_plan(20, backend="pallas").variant == "kmm2"


# ---------------------------------------------------------------------------
# Satellite: exact-int32 mode at the max_exact_k boundary.
# ---------------------------------------------------------------------------


def test_fused_exact_int32_at_max_exact_k_boundary():
    w = 12
    k = max_exact_k(w)                       # 128: the tight int32 ceiling
    a, b = runner.make_operands((16, k, 16), w, seed=3)
    plan = ExecPlan("fused", w, backend="pallas", block_m=32, block_n=32,
                    block_k=32, combine_int32=True, depth=1)
    assert space.validate(plan, (16, k, 16)) is None
    out = np.asarray(ops.run_plan_jit(a, b, plan))
    assert out.dtype == np.int32
    np.testing.assert_array_equal(
        out.astype(np.int64),
        ref_int_gemm_i64(np.asarray(a), np.asarray(b)))
    # one past the boundary: the pruner must reject the plan, and the
    # int_gemm API must refuse an exact request outright
    assert space.validate(plan, (16, k + 1, 16)) is not None
    with pytest.raises(ValueError, match="max exact K"):
        ops.int_gemm(jnp.zeros((16, k + 1), jnp.int32),
                     jnp.zeros((k + 1, 16), jnp.int32),
                     w=w, backend="pallas", exact=True)


# ---------------------------------------------------------------------------
# Satellite: grouped expert grid vs a per-expert loop.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", [8, 12])
def test_fused_grouped_matches_per_expert_loop(w):
    e, c, k, n = 3, 10, 70, 9
    rng = np.random.default_rng(w)
    lim = 2 ** (w - 1)
    a = jnp.asarray(rng.integers(-lim, lim, (e, c, k)), jnp.int32)
    b = jnp.asarray(rng.integers(-lim, lim, (e, k, n)), jnp.int32)
    kw = dict(w=w, block_m=32, block_n=32, block_k=32)
    grouped = np.asarray(fused_gemm_grouped(a, b, **kw))
    for i in range(e):
        single = np.asarray(fused_gemm(a[i], b[i], **kw))
        np.testing.assert_array_equal(grouped[i], single,
                                      err_msg=f"expert {i} diverged")
        if w <= 8:
            np.testing.assert_array_equal(
                grouped[i].astype(np.int64),
                ref_int_gemm_i64(np.asarray(a[i]), np.asarray(b[i])))


def test_fused_grouped_dequant_epilogue():
    e, c, k, n = 2, 6, 33, 5
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-2048, 2048, (e, c, k)), jnp.int32)
    b = jnp.asarray(rng.integers(-2048, 2048, (e, k, n)), jnp.int32)
    sx = jnp.asarray(rng.random((e, c, 1)), jnp.float32)
    sw = jnp.asarray(rng.random((e, 1, n)), jnp.float32)
    kw = dict(w=12, block_m=32, block_n=32, block_k=32)
    out = np.asarray(fused_gemm_grouped(a, b, sx, sw, **kw))
    acc = np.asarray(fused_gemm_grouped(a, b, **kw))
    np.testing.assert_array_equal(out, acc * np.asarray(sx * sw))


# ---------------------------------------------------------------------------
# Satellite: dequant epilogue == staged dequant, exact fp32 equality.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", [8, 12])
def test_dequant_epilogue_equals_staged_dequant(w):
    m, k, n = 17, 70, 9
    a, b = runner.make_operands((m, k, n), w, seed=w)
    rng = np.random.default_rng(w)
    sx = jnp.asarray(rng.random((m, 1)), jnp.float32)
    sw = jnp.asarray(rng.random((1, n)), jnp.float32)
    kw = dict(w=w, block_m=32, block_n=32, block_k=64)
    fused = np.asarray(fused_gemm(a, b, sx, sw, **kw))
    acc = np.asarray(fused_gemm(a, b, **kw)).astype(np.float32)
    staged_dequant = acc * np.asarray(sx * sw)
    np.testing.assert_array_equal(fused, staged_dequant)


@pytest.mark.parametrize("w", [4, 8])
def test_quantized_matmul_pallas_bit_identical_to_xla_exact_class(w):
    """In the exact-int class (w <= m) the fused pallas route computes the
    same integer as the XLA dot, and the in-kernel epilogue multiplies the
    same scales in the same order — outputs are bit-identical."""
    rng = np.random.default_rng(w)
    x = jnp.asarray(rng.standard_normal((4, 16, 32)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
    xla = np.asarray(quantized_matmul(x, wm, w))
    pal = np.asarray(quantized_matmul(x, wm, w, 8, "auto", "pallas"))
    np.testing.assert_array_equal(xla, pal)
    # batched expert path, one grouped kernel launch
    xb = jnp.asarray(rng.standard_normal((3, 8, 32)), jnp.float32)
    wb = jnp.asarray(rng.standard_normal((3, 32, 8)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(quantized_matmul_batched(xb, wb, w)),
        np.asarray(quantized_matmul_batched(xb, wb, w, 8, "auto", "pallas")))


def test_quantized_matmul_pallas_w12_close_and_bf16():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    xla = np.asarray(quantized_matmul(x, wm, 12))
    pal = np.asarray(quantized_matmul(x, wm, 12, 8, "auto", "pallas"))
    denom = max(np.abs(xla).max(), 1.0)
    assert np.abs(xla - pal).max() / denom < 1e-6   # same value, fp32 class
    out = quantized_matmul(x.astype(jnp.bfloat16), wm, 12, 8, "auto",
                           "pallas")
    assert out.dtype == jnp.bfloat16                # epilogue casts in-kernel


def test_prequant_matmul_pallas_route():
    from repro.quant.policy import POLICY_W8
    from repro.quant.prequant import prequantize

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 5, 32)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((32, 12)), jnp.float32)
    rec = prequantize({"wi": wm}, POLICY_W8)["wi"]
    assert rec["q"].dtype == jnp.int8               # narrow storage carrier
    np.testing.assert_array_equal(
        np.asarray(prequant_matmul(x, rec, 8)),
        np.asarray(prequant_matmul(x, rec, 8, backend="pallas")))


def test_pallas_route_falls_back_outside_fused_windows():
    """w=16 is the MM2 window (no fused kernel): the pallas backend must
    fall back to the XLA path, bit-identically."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(quantized_matmul(x, wm, 16)),
        np.asarray(quantized_matmul(x, wm, 16, 8, "auto", "pallas")))
    assert select_plan((4, 32, 8), 16, backend="pallas").variant == "mm2"


# ---------------------------------------------------------------------------
# Dispatch/tuning seam: fused plans stay in the staged fingerprint class.
# ---------------------------------------------------------------------------


def test_table_can_swap_fused_and_staged_without_moving_bits():
    """A tuning table recording a staged kmm2 winner is adopted over the
    fused analytic default (same fp32 fingerprint class + same K padding)
    and must not change a single output bit."""
    from repro.tune.table import TuningTable, use_table

    w, shape = 12, (64, 128, 64)
    a, b = runner.make_operands(shape, w, seed=1)
    base = np.asarray(ops.int_gemm(a, b, w=w, backend="pallas"))
    t = TuningTable()
    t.put("pallas", shape, w,
          ExecPlan("kmm2", w, backend="pallas", block_m=32, block_n=32,
                   block_k=256, combine_int32=False, depth=1))
    with use_table(t):
        plan = select_plan(shape, w, backend="pallas")
        assert plan.variant == "kmm2" and plan.source == "table"
        tabled = np.asarray(ops.int_gemm(a, b, w=w, backend="pallas"))
    np.testing.assert_array_equal(base, tabled)


def test_quantized_matmul_pallas_table_never_moves_bits():
    """Numerics pinning holds on the pallas backend too: a table that
    redirects the fused plan to a staged pallas plan (same fingerprint
    class) must leave quantized_matmul(backend='pallas') bit-identical —
    the redirect runs the staged kernel, never the XLA rounding class."""
    from repro.tune.table import TuningTable, use_table

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    t = TuningTable()
    t.put("pallas", (8, 256, 64), 12,
          ExecPlan("kmm2", 12, backend="pallas", block_m=32, block_n=64,
                   block_k=256, combine_int32=False, depth=1))
    for w in (8, 12):
        base = np.asarray(quantized_matmul(x, wm, w, 8, "auto", "pallas"))
        with use_table(t):
            tabled = np.asarray(quantized_matmul(x, wm, w, 8, "auto",
                                                 "pallas"))
        np.testing.assert_array_equal(base, tabled, err_msg=f"w={w}")


def test_pallas_route_actually_runs_fused_at_serve_shapes():
    """Tiny-M decode/prefill GEMMs must ride the fused kernel (clamped
    tiles), not silently fall back to XLA: in the fp32 class the pallas
    rounding differs from XLA's digit recursion at large K, which is
    observable — so assert the route by checking the pallas result equals
    the fused kernel's output computed directly."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)   # decode M=2
    wm = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    from repro.quant.qmatmul import _quantize, _shrink_tiles

    qx, sx = _quantize(x, 12, axis=-1)
    qw, sw = _quantize(wm, 12, axis=0)
    plan = _shrink_tiles(analytic_plan(12, backend="pallas"), (2, 64, 48))
    assert plan.tiles == (8, 64, 64)
    direct = np.asarray(fused_gemm(
        qx, qw, sx, sw, w=12, block_m=plan.block_m, block_n=plan.block_n,
        block_k=plan.block_k, out_dtype=jnp.float32))
    routed = np.asarray(quantized_matmul(x, wm, 12, 8, "auto", "pallas"))
    np.testing.assert_array_equal(routed, direct)
