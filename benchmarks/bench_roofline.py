"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape x mesh), hardware = TPU-v5e-class chip:
  compute    = HLO_FLOPs_per_device / peak_FLOPs   (197e12 bf16; 394e12 for
               int-dominated quantized serving cells)
  memory     = HLO_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9  (per-link first-order)

MODEL_FLOPS = 6*N*D (train) or 2*N_active*B (decode) gives the useful-compute
ratio; cost_analysis FLOPs and collective bytes are per-device (verified
against a known matmul in tests), so global = x n_devices.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
LINK_BW = 50e9


def model_flops(arch: str, shape: str) -> float:
    from repro.configs import SHAPES, get_config
    from repro.models.config import count_active_params, count_params

    cfg = get_config(arch)
    cell = SHAPES[shape]
    n_active = count_active_params(cfg)
    tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * cell.global_batch     # decode: one token/request


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    n_dev = rec.get("n_devices", 256)
    flops_dev = rec.get("cost", {}).get("flops", 0.0)
    bytes_dev = rec.get("cost", {}).get("bytes accessed", 0.0)
    coll_dev = rec.get("collectives", {}).get("total_bytes", 0.0)
    quant_serving = rec.get("quant", "none") != "none" and shape != "train_4k"
    peak = PEAK_INT8 if quant_serving else PEAK_BF16
    t_comp = flops_dev / peak
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(arch, shape)
    hlo_global = flops_dev * n_dev
    useful = mf / hlo_global if hlo_global else 0.0
    bound = max(t_comp, t_mem, t_coll)
    # roofline fraction: useful model flops per second at the bound vs peak
    frac = (mf / n_dev / peak) / bound if bound else 0.0
    return {
        "bench": "roofline", "arch": arch, "shape": shape, "mesh": mesh,
        "quant": rec.get("quant"),
        "compute_s": f"{t_comp:.3e}", "memory_s": f"{t_mem:.3e}",
        "collective_s": f"{t_coll:.3e}", "dominant": dominant,
        "model_flops": f"{mf:.3e}", "useful_ratio": round(useful, 3),
        "roofline_frac": round(frac, 3),
        "temp_gb": round((rec.get("memory") or {}).get("temp_bytes", 0)
                         / 1e9, 2),
    }


def run(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    paths = sorted(glob.glob(os.path.join(dryrun_dir, "*.json")))
    if not paths:
        # Never silently produce zero rows: an empty dry-run directory gets
        # an explicit marker row so BENCH_roofline.json can't read as "ran
        # and found nothing" when the sweep never ran at all.
        return [{"bench": "roofline", "name": "roofline/dryrun_artifacts",
                 "dominant": "NO_ARTIFACTS",
                 "note": f"no dry-run artifacts under {dryrun_dir}/ "
                         "(python -m repro.launch.dryrun writes them)"}]
    rows = []
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "skipped":
            rows.append({"bench": "roofline", "arch": rec["arch"],
                         "shape": rec["shape"], "mesh": rec["mesh"],
                         "dominant": "skipped", "note": rec.get("reason", "")})
            continue
        row = analyze_record(rec)
        if row:
            rows.append(row)
        elif rec.get("status") == "error":
            rows.append({"bench": "roofline", "arch": rec["arch"],
                         "shape": rec["shape"], "mesh": rec["mesh"],
                         "dominant": "ERROR",
                         "note": rec.get("error", "")[:120]})
    return rows


def checks(rows: List[Dict]):
    """Verdicts over the dry-run rows.  Missing artifacts are *not* a
    failure (the dry-run sweep is optional on dev machines) but the check
    line carries a non-empty note so the state is visible, and analyzer
    ERROR rows do fail."""
    no_art = any(r.get("dominant") == "NO_ARTIFACTS" for r in rows)
    errors = [r for r in rows if r.get("dominant") == "ERROR"]
    analyzed = [r for r in rows if "roofline_frac" in r]
    if no_art:
        note = rows[0].get("note", "dry-run artifacts absent")
    else:
        note = f"{len(analyzed)} analyzed, {len(errors)} errors"
    return [("dry-run roofline artifacts analyzed cleanly",
             not errors, note)]


def markdown_table(rows: List[Dict]) -> str:
    cols = ["arch", "shape", "mesh", "dominant", "compute_s", "memory_s",
            "collective_s", "useful_ratio", "roofline_frac", "temp_gb"]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "---|" * len(cols)]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)
