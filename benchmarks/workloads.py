"""ResNet im2col GEMM workloads (paper Tables I-II evaluate ResNet-50/101/152).

Each conv layer becomes a GEMM: M = H_out*W_out, K = C_in*k*k, N = C_out.
The metrics in Tables I-II depend only on these GEMM dims, the MXU tiling,
the pass count of the executed mode, and the clock — not on real images.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple


@dataclass(frozen=True)
class Gemm:
    m: int
    k: int
    n: int

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


def _bottleneck(m: int, c_in: int, width: int, stride: int) -> List[Gemm]:
    """1x1 reduce -> 3x3 -> 1x1 expand (+ projection on the first block)."""
    m_out = m // (stride * stride)
    out = [
        Gemm(m_out, c_in, width),            # 1x1 (stride folded into M)
        Gemm(m_out, width * 9, width),       # 3x3
        Gemm(m_out, width, width * 4),       # 1x1 expand
    ]
    if c_in != width * 4:
        out.append(Gemm(m_out, c_in, width * 4))   # projection shortcut
    return out


def resnet_gemms(depth: int, image: int = 224) -> List[Gemm]:
    blocks = {50: (3, 4, 6, 3), 101: (3, 4, 23, 3), 152: (3, 8, 36, 3)}[depth]
    g: List[Gemm] = [Gemm((image // 2) ** 2, 147, 64)]      # conv1 7x7/2
    m = (image // 4) ** 2                                    # after maxpool
    c_in = 64
    for stage, n_blocks in enumerate(blocks):
        width = 64 * 2**stage
        for b in range(n_blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            g.extend(_bottleneck(m, c_in, width, stride))
            m = m // (stride * stride)
            c_in = width * 4
    g.append(Gemm(1, 2048, 1000))                            # fc
    return g


def total_macs(depth: int) -> int:
    return sum(x.macs for x in resnet_gemms(depth))


def mxu_cycles(gemms: List[Gemm], x: int = 64, y: int = 64,
               passes: int = 1, fill: int = 64) -> int:
    """Cycle model of the paper's MXU (Fig. 7): a (y=K-rows x x=N-cols) B
    tile is preloaded (hidden by double buffering); the A tile streams M rows
    producing one output row per cycle; `fill` models pipeline fill/drain per
    tile; `passes` is the precision-scalable re-read count (1/3/4)."""
    cyc = 0
    for g in gemms:
        tiles = -(-g.k // y) * (-(-g.n // x))
        cyc += tiles * (g.m + fill)
    return cyc * passes
