"""Wall-time microbenchmarks of the integer-GEMM engine on this container.

CPU wall-times don't reflect TPU performance (the dry-run roofline does);
they validate the op-count claims end-to-end: the XLA KMM2 path must spend
~3/4 of the MM2 path's multiply work, which shows up directly in CPU time
for compute-bound sizes.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import int_gemm_jit


def _time(fn, *args, iters=5) -> float:
    fn(*args).block_until_ready()            # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    m = k = n = 1024
    rows = []
    a8 = jnp.array(rng.integers(-120, 120, (m, k)), jnp.int32)
    b8 = jnp.array(rng.integers(-120, 120, (k, n)), jnp.int32)
    lim = 2**11
    a12 = jnp.array(rng.integers(-lim, lim, (m, k)), jnp.int32)
    b12 = jnp.array(rng.integers(-lim, lim, (k, n)), jnp.int32)

    t_mm1 = _time(lambda a, b: int_gemm_jit(a, b, 8), a8, b8)
    t_kmm = _time(lambda a, b: int_gemm_jit(a, b, 12), a12, b12)
    t_mm2 = _time(lambda a, b: int_gemm_jit(a, b, 16), a12, b12)
    rows.append({"bench": "walltime", "name": "int_gemm_w8_mm1_1024",
                 "us_per_call": round(t_mm1, 1), "passes": 1})
    rows.append({"bench": "walltime", "name": "int_gemm_w12_kmm2_1024",
                 "us_per_call": round(t_kmm, 1), "passes": 3})
    rows.append({"bench": "walltime", "name": "int_gemm_w16_mm2_1024",
                 "us_per_call": round(t_mm2, 1), "passes": 4})
    ratio = t_kmm / t_mm2
    rows.append({"bench": "walltime", "name": "kmm2_over_mm2_time_ratio",
                 "us_per_call": round(ratio, 3),
                 "expect": "~0.75 (3 vs 4 digit products)"})
    return rows


def checks(rows):
    ratio = next(r["us_per_call"] for r in rows
                 if r["name"] == "kmm2_over_mm2_time_ratio")
    return [("KMM2 wall-time < MM2 wall-time (3 vs 4 products)",
             ratio < 1.0, f"ratio {ratio}")]
