"""Wall-time microbenchmarks of the integer-GEMM engine on this container.

CPU wall-times don't reflect TPU performance (the dry-run roofline does);
they validate the op-count and memory-traffic claims end-to-end:

  * the XLA KMM2 path must spend ~3/4 of the MM2 path's multiply work
    (3 vs 4 digit products), which shows up directly in CPU time for
    compute-bound sizes;
  * the fused single-pass Pallas kernel (DESIGN.md §11) must beat the
    staged plane-materializing Pallas pipeline on the large-K GEMM shapes,
    where the staged path's ~6 array-sized HBM passes (plane build, 4-plane
    kernel read, correction) dominate its overhead.

Timings are the minimum over ``REPS`` repeats (compile excluded) so the
recorded BENCH_walltime.json means are comparable across runs of the same
machine; cross-machine comparisons should normalize (see
benchmarks/check_regression.py --normalize).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import ExecPlan
from repro.kernels import ops
from repro.kernels.ops import int_gemm_jit

REPS = 5
# Large-K GEMM shapes where the fused kernel's traffic story should win,
# each with deep-K tiles — the natural (and tuner-preferred) geometry for
# K-heavy problems: both variants fit them in VMEM, both get the same
# tiles, and the per-grid-step overhead stops masking the staging-traffic
# difference.
FUSED_SHAPES = (((128, 4096, 128), 1024), ((128, 8192, 128), 2048))
FUSED_W = 12
FUSED_REPS = 12

# One fused-vs-staged family per kernel window, each at a representative
# width: the original KMM2 window (w=12), the w = 2m-1 MM2 boundary
# (w=15: fused_mm2's 4-accumulator single pass vs the staged MM2
# pipeline), and depth-2 recursion (w=20: the 9-accumulator kmm4 pass vs
# the staged two-level plane pipeline).  ``hbm_passes`` counts MXU-sized
# array passes per side (digit products for fused; plane build + plane
# reads + correction + combine for staged).
# (tag, w, fused (variant, depth), staged (variant, depth), passes)
FUSED_FAMILIES = (
    ("", FUSED_W, ("fused", 1), ("kmm2", 1), (3, 9)),
    ("mm2_", 15, ("fused_mm2", 1), ("mm2", 1), (4, 10)),
    ("d2_", 20, ("fused", 2), ("kmm2", 2), (9, 15)),
)

# Tile-level Strassen composition (core/strassen.py) at its tuned flagship
# key: w = 9 on (256, 4096, 256) with 128x128x2048 tiles sits exactly at
# the composed K bound 2**(30 - 2w) = 4096, and each of the 7 fused
# sub-GEMMs inherits the full fused launch's per-tile geometry (one
# 128x128x2048 grid step), so the three-way comparison isolates 7-vs-8
# sub-products against the fused kernel and fused-vs-XLA sub-GEMMs
# against plain strassen.
STRASSEN_SHAPES = (((256, 4096, 256), 2048),)
STRASSEN_W = 9


def _time(fn, *args, iters=2, reps=REPS) -> float:
    fn(*args).block_until_ready()            # compile + warm
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        out.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)   # us
    return best


def _fused_vs_staged_rows() -> List[Dict]:
    """Fused single-pass kernel vs the staged Pallas pipeline, same tiles.

    Both run through the production ``run_plan`` seam with the identical
    ExecPlan geometry, so the delta is exactly the staging overhead (digit
    planes + correction passes) the fusion removes.  The two are
    bit-identical by construction; the timing runs are interleaved per
    repeat so machine noise hits both sides equally.
    """
    rows = []
    rng = np.random.default_rng(0)
    for fam, w, (fv, fd), (sv, sd), (fp, sp) in FUSED_FAMILIES:
        lim = 2 ** (w - 1)
        for (m, k, n), bk in FUSED_SHAPES:
            bm = bn = 128
            a = jnp.asarray(rng.integers(-lim, lim, (m, k)), jnp.int32)
            b = jnp.asarray(rng.integers(-lim, lim, (k, n)), jnp.int32)
            fused = ExecPlan(fv, w, backend="pallas", block_m=bm,
                             block_n=bn, block_k=bk, depth=fd)
            staged = ExecPlan(sv, w, backend="pallas", block_m=bm,
                              block_n=bn, block_k=bk, depth=sd)
            fns = {"fused": lambda p=fused: ops.run_plan_jit(a, b, p),
                   "staged": lambda p=staged: ops.run_plan_jit(a, b, p)}
            for f in fns.values():
                f().block_until_ready()      # compile + warm both first
            best = {name: float("inf") for name in fns}
            for _ in range(FUSED_REPS):
                for name, f in fns.items():  # interleaved repeats
                    t0 = time.perf_counter()
                    f().block_until_ready()
                    best[name] = min(best[name],
                                     (time.perf_counter() - t0) * 1e6)
            tag = f"{m}x{k}x{n}"
            skind = sv if sd == 1 else f"{sv}d{sd}"
            fkind = "kmm2" if fam == "" else \
                ("mm2" if fv == "fused_mm2" else "kmm4")
            rows.append({"bench": "walltime",
                         "name": f"fused_{fkind}_w{w}_{tag}",
                         "us_per_call": round(best["fused"], 1),
                         "hbm_passes": fp, "shape": tag})
            rows.append({"bench": "walltime",
                         "name": f"staged_{skind}_w{w}_{tag}",
                         "us_per_call": round(best["staged"], 1),
                         "hbm_passes": sp, "shape": tag})
            suffix = f"{fam}w{w}_{tag}" if fam else tag
            rows.append({"bench": "walltime",
                         "name": f"fused_over_staged_time_ratio_{suffix}",
                         "us_per_call": round(best["fused"]
                                              / best["staged"], 3),
                         "shape": tag,
                         "expect": "< 1.0 (single-pass vs staged "
                                   "pipeline)"})
    return rows


def _strassen_rows() -> List[Dict]:
    """strassen+kmm2 vs plain strassen vs the fused kernel, one flagship key.

    All three plans are exact-int (bit-identical by the composed bound) and
    timing repeats are interleaved as in :func:`_fused_vs_staged_rows`.
    The two committed ratios are the ISSUE-10 acceptance claim —
    ``strassen+kmm2`` must beat both the plain-XLA-sub strassen AND the
    fused kmm2 kernel here — and the ``table_pick`` row records that the
    shipped tuning table actually selects it at this key (speed only; the
    fingerprint pin means the pick can never move a bit).
    """
    import json
    import os

    from repro.core.dispatch import select_plan
    from repro.tune.table import TuningTable

    rows = []
    rng = np.random.default_rng(0)
    for (m, k, n), bk in STRASSEN_SHAPES:
        w = STRASSEN_W
        lim = 2 ** (w - 1)
        bm = bn = 128
        a = jnp.asarray(rng.integers(-lim, lim, (m, k)), jnp.int32)
        b = jnp.asarray(rng.integers(-lim, lim, (k, n)), jnp.int32)
        kw = dict(block_m=bm, block_n=bn, block_k=bk, combine_int32=True)
        plans = {
            "fused": ExecPlan("fused", w, backend="pallas", depth=1, **kw),
            "xla": ExecPlan("strassen", w, backend="xla", depth=1, **kw),
            "kmm2": ExecPlan("strassen+kmm2", w, backend="pallas",
                             depth=1, **kw),
        }
        fns = {name: (lambda p=p: ops.run_plan_jit(a, b, p))
               for name, p in plans.items()}
        for f in fns.values():
            f().block_until_ready()          # compile + warm all first
        best = {name: float("inf") for name in fns}
        for _ in range(FUSED_REPS):
            for name, f in fns.items():      # interleaved repeats
                t0 = time.perf_counter()
                f().block_until_ready()
                best[name] = min(best[name],
                                 (time.perf_counter() - t0) * 1e6)
        tag = f"{m}x{k}x{n}"
        for name in plans:
            rows.append({"bench": "walltime",
                         "name": f"strassen_us_{name}_w{w}_{tag}",
                         "us_per_call": round(best[name], 1),
                         "shape": tag})
        for base in ("fused", "xla"):
            rows.append({"bench": "walltime",
                         "name": f"strassen_ratio_kmm2_over_{base}"
                                 f"_w{w}_{tag}",
                         "us_per_call": round(best["kmm2"] / best[base], 3),
                         "shape": tag,
                         "expect": "< 1.0 (7 fused sub-GEMMs vs "
                                   + ("8 full-tile products)" if base ==
                                      "fused" else "XLA sub-GEMMs)")})
        table_path = os.path.join(os.path.dirname(__file__), os.pardir,
                                  "tuned", "cpu-interpret.json")
        try:
            table = TuningTable.load(table_path)
            plan = select_plan((m, k, n), w, backend="pallas", exact=True,
                               table=table)
            rows.append({"bench": "walltime",
                         "name": f"strassen_table_pick_w{w}_{tag}",
                         "us_per_call": 1.0
                         if plan.variant == "strassen+kmm2" else 0.0,
                         "picked_variant": plan.variant,
                         "picked_source": plan.source, "shape": tag,
                         "expect": "1.0 (tuned table picks strassen+kmm2)"})
        except (OSError, ValueError, json.JSONDecodeError):
            pass
    return rows


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    m = k = n = 1024
    rows = []
    a8 = jnp.array(rng.integers(-120, 120, (m, k)), jnp.int32)
    b8 = jnp.array(rng.integers(-120, 120, (k, n)), jnp.int32)
    lim = 2**11
    a12 = jnp.array(rng.integers(-lim, lim, (m, k)), jnp.int32)
    b12 = jnp.array(rng.integers(-lim, lim, (k, n)), jnp.int32)

    t_mm1 = _time(lambda a, b: int_gemm_jit(a, b, 8), a8, b8)
    t_kmm = _time(lambda a, b: int_gemm_jit(a, b, 12), a12, b12)
    t_mm2 = _time(lambda a, b: int_gemm_jit(a, b, 16), a12, b12)
    rows.append({"bench": "walltime", "name": "int_gemm_w8_mm1_1024",
                 "us_per_call": round(t_mm1, 1), "passes": 1})
    rows.append({"bench": "walltime", "name": "int_gemm_w12_kmm2_1024",
                 "us_per_call": round(t_kmm, 1), "passes": 3})
    rows.append({"bench": "walltime", "name": "int_gemm_w16_mm2_1024",
                 "us_per_call": round(t_mm2, 1), "passes": 4})
    ratio = t_kmm / t_mm2
    rows.append({"bench": "walltime", "name": "kmm2_over_mm2_time_ratio",
                 "us_per_call": round(ratio, 3),
                 "expect": "~0.75 (3 vs 4 digit products)"})
    rows.extend(_fused_vs_staged_rows())
    rows.extend(_strassen_rows())
    return rows


def checks(rows):
    ratio = next(r["us_per_call"] for r in rows
                 if r["name"] == "kmm2_over_mm2_time_ratio")
    out = [("KMM2 wall-time < MM2 wall-time (3 vs 4 products)",
            ratio < 1.0, f"ratio {ratio}")]
    for r in rows:
        if r["name"].startswith("fused_over_staged_time_ratio"):
            out.append((f"fused beats staged Pallas pipeline "
                        f"({r['name']})",
                        r["us_per_call"] < 1.0, f"ratio {r['us_per_call']}"))
        elif r["name"].startswith("strassen_ratio_"):
            out.append((f"strassen+kmm2 wins ({r['name']})",
                        r["us_per_call"] < 1.0, f"ratio {r['us_per_call']}"))
        elif r["name"].startswith("strassen_table_pick"):
            out.append((f"tuned table picks strassen+kmm2 ({r['name']})",
                        r.get("picked_variant") == "strassen+kmm2",
                        f"picked {r.get('picked_variant')} "
                        f"({r.get('picked_source')})"))
    return out
