"""Benchmark harness entry: one reproduction per paper table/figure plus the
wall-time microbench and the dry-run roofline table.

    PYTHONPATH=src python -m benchmarks.run [--skip-walltime]

Prints ``name,us_per_call,derived`` CSV rows followed by CHECK lines that
assert the paper's claims against our implementation, and writes one
machine-readable ``BENCH_<group>.json`` per benchmark group (paper_tables /
walltime / serve / roofline) at the repo root so the perf trajectory —
tokens/s, TTFT, GEMM wall-times — is tracked across PRs.

``--tuning-table tuned/default.json`` installs a repro.tune kernel
variant/tile table before any benchmark runs (see DESIGN.md §10).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _emit(rows, checks, csv_lines, check_lines):
    for r in rows:
        name = r.get("name") or "/".join(
            str(r.get(k)) for k in ("bench", "model", "arch", "mode", "shape",
                                    "n", "w", "mesh") if r.get(k) is not None)
        us = r.get("us_per_call", 0)
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("bench", "name", "us_per_call"))
        csv_lines.append(f"{name},{us},{derived}")
    for claim, ok, detail in checks:
        check_lines.append(
            f"CHECK {'PASS' if ok else 'FAIL'}: {claim}"
            + (f" [{detail}]" if detail else ""))


def write_bench_json(group: str, rows, checks, out_dir: str) -> str:
    """Persist one benchmark group as BENCH_<group>.json (machine-readable:
    every row dict verbatim — tokens/s, TTFT percentiles, GEMM us_per_call —
    plus the CHECK verdicts)."""
    doc = {
        "bench": group,
        "rows": list(rows),
        "checks": [{"claim": c, "ok": bool(ok), "detail": d}
                   for c, ok, d in checks],
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{group}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=str)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-walltime", action="store_true")
    ap.add_argument("--skip-serve", action="store_true")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--json-dir", default=REPO_ROOT,
                    help="where BENCH_<group>.json files land "
                         "(default: repo root)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing BENCH_<group>.json files")
    ap.add_argument("--tuning-table", default=None,
                    help="repro.tune table JSON to install before running")
    ap.add_argument("--only", default=None,
                    choices=["paper_tables", "walltime", "serve", "sharded",
                             "roofline"],
                    help="run a single benchmark group (e.g. the CI "
                         "bench-regression step runs --only walltime)")
    ap.add_argument("--roofline-smoke", action="store_true",
                    help="measure traffic on the tiny SMOKE_SHAPES instead "
                         "of the tuned deep-K bench shapes (CI obs-smoke)")
    args = ap.parse_args()

    if args.tuning_table:
        from repro.core.context import ExecContext
        from repro.tune import set_active_table
        set_active_table(
            ExecContext(tuning_table=args.tuning_table).resolve_table())

    from benchmarks import bench_roofline, bench_serve, bench_sharded, \
        bench_walltime, paper_tables

    csv_lines = ["name,us_per_call,derived"]
    check_lines = []
    json_paths = []

    def record(group, rows, checks):
        _emit(rows, checks, csv_lines, check_lines)
        if not args.no_json:
            json_paths.append(write_bench_json(group, rows, checks,
                                               args.json_dir))

    def wants(group: str) -> bool:
        return args.only is None or args.only == group

    t0 = time.time()
    if wants("paper_tables"):
        pt_rows, pt_checks = [], []
        for fn in (paper_tables.fig5, paper_tables.fig11, paper_tables.fig12,
                   paper_tables.table1, paper_tables.table2,
                   paper_tables.table3):
            rows, checks = fn()
            pt_rows.extend(rows)
            pt_checks.extend(checks)
        record("paper_tables", pt_rows, pt_checks)

    if wants("walltime") and not args.skip_walltime:
        rows = bench_walltime.run()
        record("walltime", rows, bench_walltime.checks(rows))

    if wants("serve") and not args.skip_serve:
        rows = bench_serve.run()
        record("serve", rows, bench_serve.checks(rows))

    if wants("sharded") and not args.skip_walltime:
        # shard-mapped pallas vs GSPMD XLA on a 2x4 host-device mesh
        # (own subprocess: device count must be set before jax init)
        rows = bench_sharded.run()
        record("sharded", rows, bench_sharded.checks(rows))

    if wants("roofline"):
        # Measured traffic (compiled bytes-accessed, repro.obs.traffic) of
        # the fused / staged / xla GEMM paths vs the analytic plane-traffic
        # model, plus the dry-run roofline table when artifacts exist.
        from repro.obs import traffic
        shapes = traffic.SMOKE_SHAPES if args.roofline_smoke \
            else traffic.DEFAULT_SHAPES
        t_rows = traffic.all_traffic_rows(shapes)
        d_rows = bench_roofline.run(args.dryrun_dir)
        record("roofline", t_rows + d_rows,
               traffic.traffic_checks(t_rows) + bench_roofline.checks(d_rows))

    print("\n".join(csv_lines))
    print()
    print("\n".join(check_lines))
    n_fail = sum(1 for line in check_lines if "FAIL" in line)
    for p in json_paths:
        print(f"wrote {p}")
    print(f"\n{len(check_lines) - n_fail}/{len(check_lines)} checks passed "
          f"({time.time() - t0:.1f}s)")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
