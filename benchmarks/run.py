"""Benchmark harness entry: one reproduction per paper table/figure plus the
wall-time microbench and the dry-run roofline table.

    PYTHONPATH=src python -m benchmarks.run [--skip-walltime]

Prints ``name,us_per_call,derived`` CSV rows followed by CHECK lines that
assert the paper's claims against our implementation.
"""
from __future__ import annotations

import argparse
import sys
import time


def _emit(rows, checks, csv_lines, check_lines):
    for r in rows:
        name = r.get("name") or "/".join(
            str(r.get(k)) for k in ("bench", "model", "arch", "mode", "shape",
                                    "n", "w", "mesh") if r.get(k) is not None)
        us = r.get("us_per_call", 0)
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("bench", "name", "us_per_call"))
        csv_lines.append(f"{name},{us},{derived}")
    for claim, ok, detail in checks:
        check_lines.append(
            f"CHECK {'PASS' if ok else 'FAIL'}: {claim}"
            + (f" [{detail}]" if detail else ""))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-walltime", action="store_true")
    ap.add_argument("--skip-serve", action="store_true")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    args = ap.parse_args()

    from benchmarks import bench_roofline, bench_serve, bench_walltime, \
        paper_tables

    csv_lines = ["name,us_per_call,derived"]
    check_lines = []

    t0 = time.time()
    for fn in (paper_tables.fig5, paper_tables.fig11, paper_tables.fig12,
               paper_tables.table1, paper_tables.table2, paper_tables.table3):
        rows, checks = fn()
        _emit(rows, checks, csv_lines, check_lines)

    if not args.skip_walltime:
        rows = bench_walltime.run()
        _emit(rows, bench_walltime.checks(rows), csv_lines, check_lines)

    if not args.skip_serve:
        rows = bench_serve.run()
        _emit(rows, bench_serve.checks(rows), csv_lines, check_lines)

    roof_rows = bench_roofline.run(args.dryrun_dir)
    _emit(roof_rows, [], csv_lines, check_lines)

    print("\n".join(csv_lines))
    print()
    print("\n".join(check_lines))
    n_fail = sum(1 for line in check_lines if "FAIL" in line)
    print(f"\n{len(check_lines) - n_fail}/{len(check_lines)} checks passed "
          f"({time.time() - t0:.1f}s)")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
