"""Serving benchmark: continuous batching under a Poisson arrival trace.

Measures decode throughput (generated tokens/s over engine-busy time) and
time-to-first-token (mean / p95, including queueing delay) on a 1 / 2 / 4 /
8 / 64 slot ladder, on the smoke config of a dense arch through the
quantized KMM path.  Each row also reports mean live-slot occupancy: the
64-slot row serves the same 16-request trace as the 8-slot row, so bucketed
decode must keep its per-step cost flat (idle slots are free) — the
slot-scaling-cliff checks fail otherwise.  ``Engine.warm()`` pre-traces
every decode-bucket and prefill width, so the retrace check stays exact.

    PYTHONPATH=src python -m benchmarks.bench_serve
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

ARCH = "llama3.2-1b"
QUANT = "w8"
BATCH_SIZES = (1, 2, 4, 8, 64)
N_REQUESTS = 16
MAX_NEW = 8
MAX_SEQ = 64
# fast enough that requests queue behind busy slots (the smoke model
# serves one request in a few tens of ms), so wider engines overlap
ARRIVAL_RATE = 50.0   # requests/s


def _requests(cfg, rng):
    from repro.serve.engine import Request

    return [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             size=int(rng.integers(3, 14)))),
                    max_new_tokens=int(rng.integers(2, MAX_NEW + 1)))
            for _ in range(N_REQUESTS)]


def run(batch_sizes=BATCH_SIZES) -> List[Dict]:
    import jax

    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import Engine

    cfg = get_config(ARCH, smoke=True, quant=QUANT)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rows = []
    for bs in batch_sizes:
        rng = np.random.default_rng(0)   # same trace at every slot count
        engine = Engine(cfg, params, max_seq=MAX_SEQ, batch_size=bs)
        reqs = _requests(cfg, rng)
        arrivals = np.cumsum(
            rng.exponential(1.0 / ARRIVAL_RATE, size=len(reqs)))
        # pre-trace every decode-bucket / prefill width, then run one warm
        # workload so the measured run sees steady-state everything
        engine.warm()
        engine.generate(_requests(cfg, np.random.default_rng(1)))
        traces_before = dict(engine.n_traces())
        stats = engine.generate(reqs, arrival_s=arrivals.tolist())
        traces_after = dict(engine.n_traces())
        # offline (all requests at t=0): the step count measures batching
        # overlap deterministically, independent of machine speed
        offline = engine.generate(_requests(cfg, np.random.default_rng(0)))
        ttft = np.array([r.ttft_s for r in stats.requests])
        rows.append({
            "bench": "serve",
            "name": f"serve/{ARCH}/slots{bs}",
            "us_per_call": (stats.decode_s / max(stats.decode_steps, 1)) * 1e6,
            "slots": bs,
            "tokens": stats.generated_tokens,
            "tokens_per_s": round(stats.tokens_per_s, 2),
            "occupancy_pct": round(stats.occupancy_pct, 1),
            "ttft_mean_ms": round(float(ttft.mean()) * 1e3, 1),
            "ttft_p95_ms": round(float(np.percentile(ttft, 95)) * 1e3, 1),
            "decode_steps": stats.decode_steps,
            "offline_decode_steps": offline.decode_steps,
            # None when this jax build exposes no trace counters (-1
            # sentinel): 'unknown' must not read as 'zero retraces'
            "decode_retraces": (traces_after["decode"] - traces_before["decode"]
                                if traces_before["decode"] >= 0
                                and traces_after["decode"] >= 0 else None),
            "prefill_traces": traces_after["prefill"],
        })
    return rows


def checks(rows: List[Dict]):
    out = []
    out.append((f"serve bench reports tokens/s + TTFT at >= 3 slot counts",
                len(rows) >= 3 and all(r["tokens_per_s"] > 0
                                       and r["ttft_mean_ms"] > 0
                                       for r in rows),
                ";".join(f"slots{r['slots']}={r['tokens_per_s']}tok/s"
                         for r in rows)))
    if all(r["decode_retraces"] is not None for r in rows):
        out.append(("no decode retracing across serve groups "
                    "(fixed-shape jits)",
                    all(r["decode_retraces"] == 0 for r in rows),
                    ";".join(f"slots{r['slots']}:+{r['decode_retraces']}"
                             for r in rows)))
    wide = [r for r in rows if r["slots"] >= 4]
    narrow = [r for r in rows if r["slots"] == 1]
    if wide and narrow:
        # batching efficiency, measured on the offline (all-at-once) run so
        # the comparison is deterministic whatever the machine speed: the
        # wide engine overlaps requests and needs fewer batched steps
        out.append(("continuous batching: >=4 slots overlap requests "
                    "(fewer offline decode steps than 1 slot)",
                    wide[0]["offline_decode_steps"]
                    < narrow[0]["offline_decode_steps"],
                    f"steps {narrow[0]['offline_decode_steps']} -> "
                    f"{wide[0]['offline_decode_steps']}"))
    by_slots = {r["slots"]: r for r in rows}
    if {2, 4, 8} <= by_slots.keys():
        # the slot-scaling cliff: before bucketed decode, adding slots past
        # the live-request count *cost* throughput (every step ran the full
        # batch width).  Now 4- and 8-slot engines must keep up with the
        # 2-slot engine on the same trace (0.85 tolerance: wall-clock noise
        # on a shared CI box).
        t2 = by_slots[2]["tokens_per_s"]
        ok = all(by_slots[s]["tokens_per_s"] >= 0.85 * t2 for s in (4, 8))
        out.append(("no slot-scaling cliff: tokens/s at 4 and 8 slots "
                    "keeps up with 2 slots",
                    ok,
                    ";".join(f"slots{s}={by_slots[s]['tokens_per_s']}tok/s"
                             for s in (2, 4, 8))))
    if {8, 64} <= by_slots.keys():
        # idle slots are free: the 64-slot engine serves the identical
        # 16-request trace, so bucketed decode must keep its per-step cost
        # within noise of the 8-slot engine (dense decode would run a
        # 64-wide batch every step)
        u8, u64 = by_slots[8]["us_per_call"], by_slots[64]["us_per_call"]
        out.append(("idle slots are free: 64-slot decode step cost within "
                    "1.5x of 8-slot on the same trace",
                    u64 <= 1.5 * u8,
                    f"us_per_call {u8:.0f} -> {u64:.0f}"))
    return out


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    for claim, ok, detail in checks(rows):
        print(f"CHECK {'PASS' if ok else 'FAIL'}: {claim} [{detail}]")
