"""Sharded-GEMM benchmark: shard-mapped fused Pallas kernel vs sharded XLA.

Runs serve-sized quantized GEMMs on a 2x4 (data, model) host-device mesh —
the same ``quantized_matmul`` entry the models call, once with
``ExecContext(backend="pallas", mesh=mesh)`` (the shard_map path of
DESIGN.md §12: each shard runs the fused kernel on its local block) and
once with ``backend="xla"`` under the mesh (GSPMD partitions the
dot_generals).  The two are asserted allclose before timing, so the rows
compare equal-output execution paths.

The measurement needs 8 host devices, which must be configured before jax
initializes — so :func:`run` re-executes this module in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and parses its JSON.
On this CPU container the Pallas kernel runs in interpret mode, so the
absolute ratio is not a TPU prediction; the rows track that the sharded
path exists, stays correct, and how its overhead trends across PRs (no
CHECK gates pallas beating XLA here).

    PYTHONPATH=src python -m benchmarks.bench_sharded
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List

# Serve-sized quantized GEMMs (decode batch x d_model x d_ff / vocab slice):
# M divides the data axis (2), N divides the model axis (4).
SHAPES = ((32, 256, 1024), (8, 256, 2048))
W = 12
REPS = 5
MESH_SHAPE = (2, 4)
_WORKER_FLAG = "--worker"
_MARK = "BENCH_SHARDED_JSON:"


def _worker() -> List[Dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.context import ExecContext
    from repro.launch.mesh import make_mesh
    from repro.quant.qmatmul import quantized_matmul

    mesh = make_mesh(MESH_SHAPE)
    rows = []
    rng = np.random.default_rng(0)
    for m, k, n in SHAPES:
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        wm = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        x = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        wm = jax.device_put(wm, NamedSharding(mesh, P(None, "model")))
        ctx_pallas = ExecContext(backend="pallas", mesh=mesh)
        ctx_xla = ExecContext(backend="xla")
        f_pallas = jax.jit(
            lambda x, wm: quantized_matmul(x, wm, W, context=ctx_pallas))
        f_xla = jax.jit(
            lambda x, wm: quantized_matmul(x, wm, W, context=ctx_xla))
        with mesh:
            out_p = f_pallas(x, wm)
            out_x = f_xla(x, wm)
            assert np.allclose(np.asarray(out_p), np.asarray(out_x),
                               rtol=1e-5, atol=1e-5), \
                f"sharded pallas != sharded xla at {(m, k, n)}"
            best = {"pallas": float("inf"), "xla": float("inf")}
            for _ in range(REPS):
                for name, f in (("pallas", f_pallas), ("xla", f_xla)):
                    t0 = time.perf_counter()
                    f(x, wm).block_until_ready()
                    best[name] = min(best[name],
                                     (time.perf_counter() - t0) * 1e6)
        tag = f"{m}x{k}x{n}"
        rows.append({"bench": "sharded",
                     "name": f"sharded_pallas_w{W}_{tag}",
                     "us_per_call": round(best["pallas"], 1),
                     "mesh": "x".join(map(str, MESH_SHAPE)), "shape": tag})
        rows.append({"bench": "sharded",
                     "name": f"sharded_xla_w{W}_{tag}",
                     "us_per_call": round(best["xla"], 1),
                     "mesh": "x".join(map(str, MESH_SHAPE)), "shape": tag})
        rows.append({"bench": "sharded",
                     "name": f"sharded_pallas_over_xla_time_ratio_{tag}",
                     "us_per_call": round(best["pallas"] / best["xla"], 3),
                     "shape": tag,
                     "note": "interpret-mode pallas on CPU; not a TPU "
                             "prediction"})
    return rows


def run() -> List[Dict]:
    """Spawn the 8-host-device subprocess and collect its rows."""
    env = os.environ.copy()
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded", _WORKER_FLAG],
        env=env, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded bench subprocess failed:\n{proc.stdout}\n{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError(f"sharded bench emitted no rows:\n{proc.stdout}")


def checks(rows: List[Dict]):
    by_side = {"pallas": 0, "xla": 0}
    for r in rows:
        for side in by_side:
            if r["name"].startswith(f"sharded_{side}_"):
                by_side[side] += 1
    return [("sharded pallas vs sharded XLA measured on "
             f">= {len(SHAPES)} serve-sized shapes (2x4 mesh, equal outputs)",
             all(v >= len(SHAPES) for v in by_side.values()),
             ";".join(f"{r['name']}={r['us_per_call']}us" for r in rows
                      if "ratio" not in r["name"]))]


if __name__ == "__main__":
    if _WORKER_FLAG in sys.argv:
        print(_MARK + json.dumps(_worker()))
    else:
        rows = run()
        for r in rows:
            print(r)
        for claim, ok, detail in checks(rows):
            print(f"CHECK {'PASS' if ok else 'FAIL'}: {claim} [{detail}]")
