"""Bench-regression gate: fresh BENCH_<group>.json vs the committed baseline.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline BENCH_walltime.json --new /tmp/bench/BENCH_walltime.json \
        --tol 0.25 --match int_gemm fused staged \
        --normalize int_gemm_w8_mm1_1024

Compares ``us_per_call`` means of the GEMM rows (names matching any
``--match`` substring) and exits 1 if any row regressed by more than
``--tol`` (fraction; 0.25 = 25%).  Absolute CPU wall-times differ between
machines, so ``--normalize NAME`` divides every row by that row's value *in
the same file* before comparing — the gate then tracks relative GEMM-engine
regressions (e.g. the fused kernel slipping vs the MM1 baseline) instead of
host speed.  Ratio rows (``*_ratio*``) are always compared un-normalized:
they are already dimensionless.  The default ``--match`` set gates on the
int_gemm rows plus the fused-over-staged *ratio* rows (interleaved-paired
in bench_walltime, so correlated noise bursts cancel), not the raw
fused_/staged_ microsecond rows.  The PR-9 kernel windows ride the same
substrings with no extra flags: the ``fused_over_staged_time_ratio_mm2_*``
(fused_mm2 vs staged MM2, w=15) and ``..._d2_*`` (fused depth-2 vs staged
two-level, w=20) walltime rows match ``fused_over_staged``, and the
``roofline/traffic_{fused_mm2,staged_mm2,fused_d2,staged_d2,grouped}_*``
traffic rows match ``roofline/`` — all gated once the committed baseline
carries them.  The tile-level Strassen rows ride the same mechanism: the
interleaved ``strassen_ratio_kmm2_over_{fused,xla}_*`` walltime rows are in
the default ``--match`` set and the ``roofline/traffic_strassen_*`` rows
match ``roofline/``.  Rows DROPPED from the new run fail the gate, and so
does a ``--match`` token that matches no rows in *either* file — a renamed
row family with a regenerated baseline would otherwise leave the gate
silently while it kept "passing" on the remaining tokens.

Serve-throughput rows are gated too: pass ``--serve-baseline
BENCH_serve.json --serve-new /tmp/bench/BENCH_serve.json`` and the
``tokens_per_s`` of every serve row is compared *higher-is-better*,
normalized by the single-slot row in the same file (host speed cancels; the
gated quantity is the batching-scaling curve, e.g. slots4/slots1 falling
off a cliff).  The serve rows use their own looser ``--serve-tol`` (default
50%): the scaling curve swings ±25% run-to-run from scheduler noise on
shared CI hosts, so the serve gate is a cliff detector, not a
percent-level tracker like the interleaved GEMM ratios.

Measured-traffic rows are gated with ``--roofline-baseline
BENCH_roofline.json --roofline-new /tmp/bench/BENCH_roofline.json``: the
``measured_over_analytic`` ratio of every ``roofline/traffic_*`` row —
compiled bytes-accessed over the analytic plane-traffic model — may not
rise more than ``--roofline-tol`` (default 10%) above the committed
baseline.  The ratio is deterministic compiler output (no wall-clock in
it), so the gate needs no normalization and a tight tolerance holds; a
kernel change that adds an HBM pass moves the ratio far more than 10%.
NEW rows (a widened shape sweep) are surfaced un-gated like the other
groups.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

SERVE_NORMALIZE = "serve/llama3.2-1b/slots1"


def load_rows(path: str, metric: str = "us_per_call") -> Dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for row in doc.get("rows", []):
        name, val = row.get("name"), row.get(metric)
        if name and isinstance(val, (int, float)) and val > 0:
            out[str(name)] = float(val)
    return out


def compare(base: Dict[str, float], new: Dict[str, float], tol: float,
            match, normalize: str = "", higher_better: bool = False) -> int:
    """Print a comparison table; return the number of regressed/dropped rows.

    ``higher_better`` flips the direction (throughput rows): a row regresses
    when the new value falls more than ``tol`` below baseline.
    """
    def norm(rows: Dict[str, float], name: str) -> float:
        if "ratio" in name or not normalize:
            return rows[name]
        ref = rows.get(normalize)
        if not ref:
            raise SystemExit(f"--normalize row {normalize!r} missing/zero")
        return rows[name] / ref
    shared = sorted(set(base) & set(new))
    if match:
        shared = [n for n in shared if any(tok in n for tok in match)]
    if not shared:
        raise SystemExit("no shared GEMM rows to compare "
                         f"(match={list(match)})")
    n_fail = 0
    # A --match token that matches NOTHING in either file is a stale gate:
    # a whole row family was renamed (and the baseline regenerated in the
    # same change), so every row it used to gate silently left the
    # comparison while other tokens kept it "passing".  Dropped individual
    # rows are caught below; this catches the rename-plus-regenerate case.
    for tok in match or ():
        if not any(tok in n for n in base) and not any(tok in n for n in new):
            print(f"--match token {tok!r} matches no rows in either file "
                  f"(stale gate)")
            n_fail += 1
    for name in shared:
        b, v = norm(base, name), norm(new, name)
        reg = (b / v - 1.0) if higher_better else (v / b - 1.0)
        status = "ok"
        if reg > tol:
            status = f"REGRESSED > {tol:.0%}"
            n_fail += 1
        print(f"{name:44s} base {b:12.4g}  new {v:12.4g}  "
              f"{reg:+7.1%}  {status}")
    missing = sorted(n for n in base if n not in new
                     and (not match or any(tok in n for tok in match)))
    for name in missing:
        print(f"{name:44s} DROPPED from new run")
        n_fail += 1
    # rows only in the new run (e.g. a widened serve slot ladder) are
    # surfaced, not silently skipped: they become gated once the committed
    # baseline picks them up, and until then the comparison stays strictly
    # like-for-like
    added = sorted(n for n in new if n not in base
                   and (not match or any(tok in n for tok in match)))
    for name in added:
        print(f"{name:44s} NEW (no baseline)  new {norm(new, name):12.4g}")
    return n_fail


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail on >tol wall-time regressions of the GEMM groups "
                    "vs the committed BENCH json baseline.")
    ap.add_argument("--baseline", default="BENCH_walltime.json")
    ap.add_argument("--new", required=True)
    ap.add_argument("--tol", type=float, default=0.25)
    ap.add_argument("--match", nargs="*",
                    default=("int_gemm", "fused_over_staged",
                             "strassen_ratio"),
                    help="row-name substrings that define the GEMM groups. "
                         "Default gates on the XLA int_gemm rows and the "
                         "paired fused/staged + strassen ratio rows — the "
                         "raw fused_/staged_/strassen_us rows ride "
                         "machine-noise bursts that the interleaved ratio "
                         "cancels, so the ratio is the stable form of the "
                         "same claim")
    ap.add_argument("--normalize", default="",
                    help="row name to divide all non-ratio rows by "
                         "(cancels host speed for cross-machine runs)")
    ap.add_argument("--serve-baseline", default=None,
                    help="committed BENCH_serve.json: gates tokens_per_s "
                         "of the serve rows (higher-is-better)")
    ap.add_argument("--serve-new", default=None,
                    help="fresh BENCH_serve.json to compare against "
                         "--serve-baseline")
    ap.add_argument("--serve-normalize", default=SERVE_NORMALIZE,
                    help="serve row to divide throughputs by within each "
                         "file (single-slot row: the gate then tracks the "
                         "batching-scaling curve, not host speed)")
    ap.add_argument("--serve-tol", type=float, default=0.5,
                    help="tolerance for the serve rows (looser than --tol: "
                         "the scaling curve rides scheduler noise on shared "
                         "CI hosts; 0.5 still catches a slot-scaling cliff)")
    ap.add_argument("--roofline-baseline", default=None,
                    help="committed BENCH_roofline.json: gates the "
                         "measured/analytic traffic ratio of every "
                         "roofline/traffic_* row (lower-is-better; the "
                         "ratio is deterministic compiler output, so the "
                         "gate is machine-independent with no --normalize)")
    ap.add_argument("--roofline-new", default=None,
                    help="fresh BENCH_roofline.json to compare against "
                         "--roofline-baseline")
    ap.add_argument("--roofline-tol", type=float, default=0.10,
                    help="tolerance for the traffic-ratio rows: a GEMM "
                         "path's measured bytes may not drift more than "
                         "this fraction above its committed ratio")
    args = ap.parse_args(argv)
    n_fail = compare(load_rows(args.baseline), load_rows(args.new),
                     args.tol, tuple(args.match), args.normalize)
    if (args.serve_baseline is None) != (args.serve_new is None):
        raise SystemExit("--serve-baseline and --serve-new go together")
    if args.serve_new is not None:
        print()
        n_fail += compare(
            load_rows(args.serve_baseline, metric="tokens_per_s"),
            load_rows(args.serve_new, metric="tokens_per_s"),
            args.serve_tol, ("serve/",), args.serve_normalize,
            higher_better=True)
    if (args.roofline_baseline is None) != (args.roofline_new is None):
        raise SystemExit("--roofline-baseline and --roofline-new go together")
    if args.roofline_new is not None:
        print()
        n_fail += compare(
            load_rows(args.roofline_baseline, metric="measured_over_analytic"),
            load_rows(args.roofline_new, metric="measured_over_analytic"),
            args.roofline_tol, ("roofline/",))
    if n_fail:
        print(f"\n{n_fail} row(s) regressed beyond tolerance")
        return 1
    print("\nno GEMM regressions beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
