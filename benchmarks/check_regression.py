"""Bench-regression gate: fresh BENCH_<group>.json vs the committed baseline.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline BENCH_walltime.json --new /tmp/bench/BENCH_walltime.json \
        --tol 0.25 --match int_gemm fused staged \
        --normalize int_gemm_w8_mm1_1024

Compares ``us_per_call`` means of the GEMM rows (names matching any
``--match`` substring) and exits 1 if any row regressed by more than
``--tol`` (fraction; 0.25 = 25%).  Absolute CPU wall-times differ between
machines, so ``--normalize NAME`` divides every row by that row's value *in
the same file* before comparing — the gate then tracks relative GEMM-engine
regressions (e.g. the fused kernel slipping vs the MM1 baseline) instead of
host speed.  Ratio rows (``*_ratio*``) are always compared un-normalized:
they are already dimensionless.  The default ``--match`` set gates on the
int_gemm rows plus the fused-over-staged *ratio* rows (interleaved-paired
in bench_walltime, so correlated noise bursts cancel), not the raw
fused_/staged_ microsecond rows.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict


def load_rows(path: str) -> Dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for row in doc.get("rows", []):
        name, us = row.get("name"), row.get("us_per_call")
        if name and isinstance(us, (int, float)) and us > 0:
            out[str(name)] = float(us)
    return out


def compare(base: Dict[str, float], new: Dict[str, float], tol: float,
            match, normalize: str = "") -> int:
    def norm(rows: Dict[str, float], name: str) -> float:
        if "ratio" in name or not normalize:
            return rows[name]
        ref = rows.get(normalize)
        if not ref:
            raise SystemExit(f"--normalize row {normalize!r} missing/zero")
        return rows[name] / ref
    shared = sorted(set(base) & set(new))
    if match:
        shared = [n for n in shared if any(tok in n for tok in match)]
    if not shared:
        raise SystemExit("no shared GEMM rows to compare "
                         f"(match={list(match)})")
    n_fail = 0
    for name in shared:
        b, v = norm(base, name), norm(new, name)
        reg = v / b - 1.0
        status = "ok"
        if reg > tol:
            status = f"REGRESSED > {tol:.0%}"
            n_fail += 1
        print(f"{name:44s} base {b:12.4g}  new {v:12.4g}  "
              f"{reg:+7.1%}  {status}")
    missing = sorted(n for n in base if n not in new
                     and (not match or any(tok in n for tok in match)))
    for name in missing:
        print(f"{name:44s} DROPPED from new run")
        n_fail += 1
    return n_fail


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail on >tol wall-time regressions of the GEMM groups "
                    "vs the committed BENCH json baseline.")
    ap.add_argument("--baseline", default="BENCH_walltime.json")
    ap.add_argument("--new", required=True)
    ap.add_argument("--tol", type=float, default=0.25)
    ap.add_argument("--match", nargs="*",
                    default=("int_gemm", "fused_over_staged"),
                    help="row-name substrings that define the GEMM groups. "
                         "Default gates on the XLA int_gemm rows and the "
                         "paired fused/staged ratio rows — the raw "
                         "fused_/staged_ us rows ride machine-noise bursts "
                         "that the interleaved ratio cancels, so the ratio "
                         "is the stable form of the same claim")
    ap.add_argument("--normalize", default="",
                    help="row name to divide all non-ratio rows by "
                         "(cancels host speed for cross-machine runs)")
    args = ap.parse_args(argv)
    n_fail = compare(load_rows(args.baseline), load_rows(args.new),
                     args.tol, tuple(args.match), args.normalize)
    if n_fail:
        print(f"\n{n_fail} GEMM row(s) regressed beyond {args.tol:.0%}")
        return 1
    print("\nno GEMM regressions beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
