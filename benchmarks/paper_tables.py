"""Reproductions of every paper table/figure, one function each.

Each returns (rows, checks): ``rows`` = list of dicts (printed as CSV by
run.py); ``checks`` = list of (claim, ok, detail) asserting the paper's
qualitative/quantitative statements against our implementation.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

from repro.core.area import area_kmm, area_ksmm, area_mm1, au_efficiency_vs_mm1
from repro.core.complexity import kmm_arith, ksmm_arith, mm_arith
from repro.core.dispatch import select_mode
from repro.core.efficiency import precision_scalable_roof, roof
from benchmarks.workloads import mxu_cycles, resnet_gemms

Check = Tuple[str, bool, str]


# ---------------------------------------------------------------------------
# Fig. 5 — arithmetic complexity of MM_n / KSMM_n relative to KMM_n (d=64).
# ---------------------------------------------------------------------------


def fig5(d: int = 64):
    rows, checks = [], []
    for n in (2, 4, 8, 16, 32):
        r_mm = mm_arith(n, d) / kmm_arith(n, d)
        r_ksmm = ksmm_arith(n, d) / kmm_arith(n, d)
        rows.append({"bench": "fig5", "n": n, "d": d,
                     "mm_over_kmm": round(r_mm, 3),
                     "ksmm_over_kmm": round(r_ksmm, 3)})
    checks.append(("KSMM_n > 1.75x KMM_n ops (all n)",
                   all(r["ksmm_over_kmm"] > 1.75 for r in rows), ""))
    checks.append(("KMM < MM from n=2",
                   rows[0]["mm_over_kmm"] > 1.0,
                   f"n=2 ratio {rows[0]['mm_over_kmm']}"))
    checks.append(("KSMM < MM only for n > 4",
                   ksmm_arith(4, d) > mm_arith(4, d)
                   and ksmm_arith(8, d) < mm_arith(8, d), ""))
    return rows, checks


# ---------------------------------------------------------------------------
# Fig. 11 — precision-scalable multiplier compute efficiency roofs (m=8).
# ---------------------------------------------------------------------------


def fig11(m: int = 8):
    rows, checks = [], []
    for w in range(2, 17):
        rows.append({
            "bench": "fig11", "w": w,
            "mm2_roof": round(precision_scalable_roof("mm", w, m), 3),
            "kmm2_roof": round(precision_scalable_roof("kmm", w, m), 3),
            "mode": select_mode(w, m).mode.value,
        })
    in_window = [r for r in rows if 9 <= r["w"] <= 14]
    checks.append(("KMM roof = 4/3 for w in 9..14",
                   all(abs(r["kmm2_roof"] - 4 / 3) < 1e-3 for r in in_window),
                   ""))
    checks.append(("MM roof = 1 everywhere",
                   all(abs(r["mm2_roof"] - 1.0) < 1e-9 for r in rows), ""))
    return rows, checks


# ---------------------------------------------------------------------------
# Fig. 12 — AU compute efficiency of fixed-precision architectures.
# ---------------------------------------------------------------------------


def fig12():
    rows, checks = [], []
    for w in (8, 16, 24, 32, 40, 48, 56, 64):
        kmm = au_efficiency_vs_mm1("kmm", w)
        ksmm = au_efficiency_vs_mm1("ksmm", w, n=2)
        rows.append({"bench": "fig12", "w": w,
                     "kmm_vs_mm1": round(kmm.relative, 3),
                     "ksmm_vs_mm1": round(ksmm.relative, 3)})
    checks.append(("KMM crosses MM1 at lower w than KSMM",
                   next(r["w"] for r in rows if r["kmm_vs_mm1"] > 1)
                   < next(r["w"] for r in rows if r["ksmm_vs_mm1"] > 1), ""))
    checks.append(("KMM >= KSMM at every width",
                   all(r["kmm_vs_mm1"] > r["ksmm_vs_mm1"] for r in rows), ""))
    return rows, checks


# ---------------------------------------------------------------------------
# Table I — precision-scalable KMM vs MM system model (ResNets, 64x64 MXU).
# ---------------------------------------------------------------------------

_PAPER_T1 = {   # depth: (mm2_eff_8bit, kmm_eff_9_14) from Table I
    50: (0.792, 1.055), 101: (0.865, 1.154), 152: (0.898, 1.197),
}
_FREQ = {"mm2": 320e6, "kmm2": 326e6}
_FILL = 32   # pipeline fill/drain per tile (calibrated; see workloads.py)


def table1():
    rows, checks = [], []
    n_mult = 64 * 64
    for depth, (eff8_paper, effk_paper) in _PAPER_T1.items():
        g = resnet_gemms(depth)
        macs = sum(x.macs for x in g)
        for mode, passes, wlab in (("mm1", 1, "1-8"), ("kmm2", 3, "9-14"),
                                   ("mm2", 4, "15-16")):
            cyc = mxu_cycles(g, passes=passes, fill=_FILL)
            # Eq. 12: conventional m-bit mult count / (cycles * multipliers);
            # w>8 conventional algebra needs 4 passes (Eq. 13)
            conv = macs * (1 if wlab == "1-8" else 4)
            eff = conv / (cyc * n_mult)
            f = _FREQ["kmm2"] if mode == "kmm2" else _FREQ["mm2"]
            gops = 2 * macs / (cyc / f) / 1e9
            rows.append({"bench": "table1", "model": f"resnet-{depth}",
                         "mode": mode, "w": wlab,
                         "eff_model": round(eff, 3), "gops_model": round(gops),
                         "eff_paper": eff8_paper if wlab == "1-8"
                         else (effk_paper if wlab == "9-14" else
                               round(eff8_paper, 3))})
        ours = [r for r in rows if r["model"] == f"resnet-{depth}"]
        kmm_eff = next(r["eff_model"] for r in ours if r["mode"] == "kmm2")
        mm1_eff = next(r["eff_model"] for r in ours if r["mode"] == "mm1")
        checks.append((f"resnet-{depth}: KMM2 eff = 4/3 x 8-bit eff",
                       abs(kmm_eff / mm1_eff - 4 / 3) < 5e-3,
                       f"{kmm_eff}/{mm1_eff}"))
        checks.append((f"resnet-{depth}: KMM2 eff surpasses prior-work roof 1",
                       kmm_eff > 1.0, f"{kmm_eff}"))
        checks.append((f"resnet-{depth}: model within 6% of paper Table I",
                       abs(mm1_eff - eff8_paper) / eff8_paper < 0.06,
                       f"model {mm1_eff} vs paper {eff8_paper}"))
    return rows, checks


# ---------------------------------------------------------------------------
# Table II — FFIP and FFIP+KMM combined roofs/system model.
# ---------------------------------------------------------------------------


def table2():
    rows, checks = [], []
    n_mult = 64 * 32   # FFIP MXU: half the multipliers (64x64-equivalent)
    for depth in (50, 101, 152):
        g = resnet_gemms(depth)
        macs = sum(x.macs for x in g)
        for mode, passes, wlab, mult_factor in (
                ("ffip", 1, "1-8", 2.0), ("ffip_kmm2", 3, "9-14", 2.0),
                ("ffip_mm2", 4, "15-16", 2.0)):
            # FFIP: each PE multiplier covers TWO MACs, so the 64x32-mult
            # array sustains a 64x64 MAC tile per pass (paper [6]).
            cyc = mxu_cycles(g, x=64, y=64, passes=passes, fill=_FILL)
            conv = macs * (1 if wlab == "1-8" else 4)
            eff = conv / (cyc * n_mult)
            rows.append({"bench": "table2", "model": f"resnet-{depth}",
                         "mode": mode, "w": wlab, "eff_model": round(eff, 3)})
        ours = [r for r in rows if r["model"] == f"resnet-{depth}"]
        e_ffip = next(r["eff_model"] for r in ours if r["mode"] == "ffip")
        e_combo = next(r["eff_model"] for r in ours
                       if r["mode"] == "ffip_kmm2")
        checks.append((f"resnet-{depth}: FFIP+KMM surpasses FFIP limit 2",
                       e_combo > 2.0, f"{e_combo}"))
        checks.append((f"resnet-{depth}: FFIP+KMM approaches 8/3",
                       2.0 < e_combo < 8 / 3 + 1e-9, f"{e_combo} vs 2.667"))
    checks.append(("roof algebra: ffip=2, ffip+kmm=8/3 at w=16",
                   roof("ffip", 16, 8) == 2.0
                   and abs(roof("ffip_kmm", 16, 8) - 8 / 3) < 1e-9, ""))
    return rows, checks


# ---------------------------------------------------------------------------
# Table III — fixed-precision DSP/area/frequency model (Agilex 7).
# ---------------------------------------------------------------------------

_PAPER_T3 = {
    # arch: (dsps, alms_k, freq_mhz) from Table III (non-pipelined variants)
    ("mm1", 32): (2048, 64, 450), ("ksmm", 32): (1536, 138, 386),
    ("kmm", 32): (1536, 68, 622),
    ("mm1", 64): (8704, 240, 203), ("ksmm", 64): (4608, 554, 147),
    ("kmm", 64): (4608, 212, 552),
}


def table3():
    """DSP counts follow multiplication counts (2 mults/DSP on Agilex);
    ALM trends follow the AU adder model; frequencies are synthesis facts we
    report from the paper (no TPU analogue — DESIGN.md §9)."""
    rows, checks = [], []
    xy = 32 * 32
    for (arch, w), (dsps_p, alms_p, freq_p) in _PAPER_T3.items():
        n = 2 if w == 32 else 4
        r = int(math.log2(n))
        if arch == "mm1":
            mults = xy * 4**r
            area = area_mm1(w, x=32, y=32)
        elif arch == "ksmm":
            mults = xy * 3**r
            area = area_ksmm(n, w, x=32, y=32)
        else:
            mults = xy * 3**r
            area = area_kmm(n, w, x=32, y=32)
        dsps_model = mults // 2
        rows.append({"bench": "table3", "arch": arch, "w": w,
                     "dsps_model": dsps_model, "dsps_paper": dsps_p,
                     "au_area_k": round(area / 1e3), "alms_paper_k": alms_p,
                     "freq_paper_mhz": freq_p})
    for w in (32, 64):
        ours = {r["arch"]: r for r in rows if r["w"] == w}
        checks.append((f"w={w}: KMM/KSMM use 3^r mults vs MM1 4^r (DSP dip)",
                       ours["kmm"]["dsps_model"] < ours["mm1"]["dsps_model"],
                       ""))
        checks.append((f"w={w}: KMM model DSPs within 25% of paper",
                       abs(ours["kmm"]["dsps_model"] - ours["kmm"]["dsps_paper"])
                       / ours["kmm"]["dsps_paper"] < 0.25,
                       f"{ours['kmm']['dsps_model']} vs {ours['kmm']['dsps_paper']}"))
        checks.append((f"w={w}: KMM soft-logic area < KSMM (ALM reduction)",
                       ours["kmm"]["au_area_k"] < ours["ksmm"]["au_area_k"],
                       ""))
    return rows, checks
